"""Design a heterogeneous network from a switch inventory — first with the
paper's two *rules* (and what breaking each rule costs), then with the
paper's *method*: hand the pool to the fleet optimizer and search server
placement + interconnect for throughput directly.

  1. attach servers in proportion to port count (§5.1),
  2. wire the remaining ports uniformly at random; any healthy amount of
     cross-cluster connectivity sits on the plateau (§5.2/§6.2) — but
     starving the cut collapses throughput, at the analytically predicted
     point C-bar* (Eqn. 2).

    PYTHONPATH=src python examples/design_heterogeneous.py
"""
from repro.core import Sweep, bounds, heterogeneous as het, run_sweep

spec = het.TwoClassSpec(n_large=10, k_large=18, n_small=20, k_small=6,
                        num_servers=90)

print(f"inventory: {spec.n_large} x {spec.k_large}-port + "
      f"{spec.n_small} x {spec.k_small}-port switches, "
      f"{spec.num_servers} servers")

def measure(servers_on_large, bias, label):
    # a one-point declarative sweep: 3 seeded runs, one solve_batch call
    pt, = run_sweep(
        Sweep(xs=(bias,), runs=3, seed0=0),
        lambda x, seed: het.build_two_class(spec, servers_on_large, x, seed),
        engine="exact")
    print(f"  {label:42s}: throughput {pt.mean:.3f} (+-{pt.std:.3f})")
    return pt.mean

prop = spec.proportional_large_servers
print("\npaper design (proportional + vanilla random):")
t_star = measure(prop, 1.0, "servers prop. to ports, bias=1.0")

print("\nbreaking rule 1 (server placement):")
measure(int(0.4 * prop), 1.0, "servers packed on small switches")
measure(min(int(1.6 * prop), spec.num_servers), 1.0,
        "servers packed on large switches")

print("\nbreaking rule 2 (cross-cluster cut):")
measure(prop, 0.5, "half the random cross-links (still plateau)")
measure(prop, 0.1, "10% cross-links (starved cut)")

# where must the collapse start?  Eqn 2: C-bar* = T* 2 n1 n2/(n1+n2)
topo = het.build_two_class(spec, prop, 1.0, 7)
n1 = int(topo.servers[topo.labels == 1].sum())
n2 = int(topo.servers[topo.labels == 0].sum())
cbar_star = bounds.cut_threshold(t_star, n1, n2)
cbar_vanilla = topo.cut_capacity(topo.labels == 1)
print(f"\nEqn-2 threshold: throughput must drop once the cut < "
      f"{cbar_star:.0f} links (vanilla random gives {cbar_vanilla:.0f} -> "
      f"{cbar_vanilla / cbar_star:.1f}x headroom for flexible cabling)")

# --- the method, not the recipe: fleet search over the same pool ----------
print("\nfleet search over the same pool (repro.design, certified bounds):")
result = het.optimize_spec(spec, rounds=3, fleet=8, elite=3, runs=2, seed=0)
ref, best = result.reference, result.best
print(f"  paper recipe (reference) : certified lb {ref.lb:.3f} "
      f"(ub {ref.ub:.3f})")
print(f"  optimizer-found design   : certified lb {best.lb:.3f} "
      f"(ub {best.ub:.3f}), params {dict(best.cand.params)}")
print(f"  search cost: {result.stats['search_executes']} BatchPlan "
      f"executes, compile keys {list(result.stats['compile_keys'])}")
