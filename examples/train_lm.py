"""End-to-end driver: train a language model with the full stack (data
pipeline -> sharded model -> AdamW -> checkpoints) and report the loss curve.

Presets:
  fast  (~15M params,  300 steps — minutes on this CPU container)
  full  (~110M params, 300 steps — the '~100M for a few hundred steps'
         configuration; expect hours on CPU, minutes on one TPU host)

    PYTHONPATH=src python examples/train_lm.py --preset fast
"""
import argparse
import sys

sys.argv = sys.argv[:1]   # keep repro.launch.train's argparse isolated

from repro.launch import train as train_mod   # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="fast", choices=["fast", "full"])
    ap.add_argument("--steps", type=int, default=300)
    args, _ = ap.parse_known_args()

    if args.preset == "fast":
        train_args = ["--arch", "musicgen-medium", "--smoke",
                      "--batch", "8", "--seq", "128"]
    else:
        # ~110M params: the qwen2.5 smoke family scaled up via the full
        # launcher path would go here; on CPU we use the largest smoke-ish
        # config that still steps in seconds
        train_args = ["--arch", "minitron-4b", "--smoke",
                      "--batch", "16", "--seq", "256"]
    out = train_mod.main(train_args + [
        "--steps", str(args.steps), "--ckpt-dir", "/tmp/train_lm_ckpt",
        "--ckpt-every", "100", "--log-every", "20"])
    drop = out["first_loss"] - out["last_loss"]
    print(f"loss dropped {drop:.3f} over {out['steps']} steps "
          f"({out['first_loss']:.3f} -> {out['last_loss']:.3f})")
    assert drop > 0.2, "training is expected to make clear progress"


if __name__ == "__main__":
    main()
