"""Quickstart: how close is a random graph to the throughput bound?

Builds a Jellyfish-style random regular graph, measures max-concurrent-flow
throughput for a random-permutation workload with the exact HiGHS LP AND
the JAX certified-bracket engine (the fused Frank–Wolfe primal + dual
descent: a [lb, ub] bracket that provably contains the LP optimum) through
the unified ``get_engine`` API, and compares against the paper's universal
upper bound (Theorem 1 + the Cerf et al. ASPL bound).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import bounds, get_engine, graphs, lp, traffic

N, DEGREE, SERVERS_PER_SWITCH = 32, 8, 4

topo = graphs.random_regular_graph(N, DEGREE, seed=0,
                                   servers=SERVERS_PER_SWITCH)
dem = traffic.make("permutation", topo.servers, seed=1)

exact = get_engine("exact").solve(topo, dem)
cert = get_engine("certified", iters=600).solve(topo, dem)
lb, ub, gap = cert.meta["lb"], cert.meta["ub"], cert.meta["gap"]
assert lb <= exact.throughput * (1 + 1e-4) and \
    exact.throughput <= ub * (1 + 1e-4), "bracket must contain the optimum"

f = traffic.num_flows(dem)
d_real = lp.aspl_hops(topo, dem)
ub_real_d = bounds.throughput_upper_bound(N, DEGREE, f, aspl=d_real)
ub_universal = bounds.throughput_upper_bound(N, DEGREE, f)

print(f"RRG({N}, deg={DEGREE}), {topo.num_servers} servers, "
      f"{int(f)} flows")
print(f"  throughput (exact LP)        : {exact.throughput:.4f}")
print(f"  certified bracket (JAX)      : [{lb:.4f}, {ub:.4f}] "
      f"(gap {100 * gap:.2f}%, no LP needed)")
print(f"  Thm-1 bound (measured <D>)   : {ub_real_d:.4f}")
print(f"  Thm-1 + d* universal bound   : {ub_universal:.4f}")
print(f"  fraction of optimal achieved : "
      f">= {exact.throughput / ub_universal:.1%}")
