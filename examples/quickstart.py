"""Quickstart: how close is a random graph to the throughput bound?

Builds a Jellyfish-style random regular graph, measures max-concurrent-flow
throughput for a random-permutation workload with BOTH engines (exact HiGHS
LP and the JAX dual solver) through the unified ``get_engine`` API, and
compares against the paper's universal upper bound (Theorem 1 + the Cerf et
al. ASPL bound).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import bounds, get_engine, graphs, lp, traffic

N, DEGREE, SERVERS_PER_SWITCH = 32, 8, 4

topo = graphs.random_regular_graph(N, DEGREE, seed=0,
                                   servers=SERVERS_PER_SWITCH)
dem = traffic.make("permutation", topo.servers, seed=1)

exact = get_engine("exact").solve(topo, dem)
dual = get_engine("dual", iters=600).solve(topo, dem)

f = traffic.num_flows(dem)
d_real = lp.aspl_hops(topo, dem)
ub_real_d = bounds.throughput_upper_bound(N, DEGREE, f, aspl=d_real)
ub_universal = bounds.throughput_upper_bound(N, DEGREE, f)

print(f"RRG({N}, deg={DEGREE}), {topo.num_servers} servers, "
      f"{int(f)} flows")
print(f"  throughput (exact LP)        : {exact.throughput:.4f}")
print(f"  throughput (JAX dual bound)  : {dual.throughput:.4f} "
      f"({100 * (dual.throughput / exact.throughput - 1):+.2f}%)")
print(f"  Thm-1 bound (measured <D>)   : {ub_real_d:.4f}")
print(f"  Thm-1 + d* universal bound   : {ub_universal:.4f}")
print(f"  fraction of optimal achieved : "
      f">= {exact.throughput / ub_universal:.1%}")
