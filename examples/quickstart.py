"""Quickstart: how close is a random graph to the throughput bound?

Builds a Jellyfish-style random regular graph, measures max-concurrent-flow
throughput for a random-permutation workload with BOTH engines (exact HiGHS
LP and the JAX dual solver), and compares against the paper's universal
upper bound (Theorem 1 + the Cerf et al. ASPL bound).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import bounds, graphs, lp, mcf, traffic

N, DEGREE, SERVERS_PER_SWITCH = 32, 8, 4

cap = graphs.random_regular_graph(N, DEGREE, seed=0)
servers = np.full(N, SERVERS_PER_SWITCH)
dem = traffic.random_permutation(servers, seed=1)

exact = lp.max_concurrent_flow(cap, dem, want_flows=False).throughput
dual = mcf.solve_dual(cap, dem, iters=600)

f = traffic.num_flows(dem)
d_real = lp.aspl_hops(cap, dem)
ub_real_d = bounds.throughput_upper_bound(N, DEGREE, f, aspl=d_real)
ub_universal = bounds.throughput_upper_bound(N, DEGREE, f)

print(f"RRG({N}, deg={DEGREE}), {int(servers.sum())} servers, "
      f"{int(f)} flows")
print(f"  throughput (exact LP)        : {exact:.4f}")
print(f"  throughput (JAX dual bound)  : {dual.throughput_ub:.4f} "
      f"({100 * (dual.throughput_ub / exact - 1):+.2f}%)")
print(f"  Thm-1 bound (measured <D>)   : {ub_real_d:.4f}")
print(f"  Thm-1 + d* universal bound   : {ub_universal:.4f}")
print(f"  fraction of optimal achieved : >= {exact / ub_universal:.1%}")
