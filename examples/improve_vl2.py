"""Reproduce the paper's headline result (Fig. 11): rewiring VL2's exact
equipment — ToR uplinks spread over agg+core in proportion to port count,
remaining ports wired uniformly at random — supports more servers at full
throughput.  Then hand the same equipment to the fleet optimizer
(``repro.design``) and let it SEARCH wirings instead of replaying the
hand-coded recipe.

    PYTHONPATH=src python examples/improve_vl2.py
"""
from repro.core import get_engine, traffic, vl2

spec = vl2.VL2Spec(d_a=6, d_i=6, servers_per_tor=20)
base = spec.n_tor_full

print(f"VL2(D_A={spec.d_a}, D_I={spec.d_i}): {spec.n_agg} agg + "
      f"{spec.n_core} core switches, {spec.servers_per_tor} servers/ToR")
print(f"  stock VL2 supports {base} ToRs "
      f"({base * spec.servers_per_tor} servers) at full throughput")

topo = vl2.vl2_topology(spec)
dem = traffic.make("permutation", topo.servers, 0)
th = get_engine("exact").solve(topo, dem).throughput
print(f"  (verified: theta = {th:.2f} >= 1)")

best = vl2.max_tors_at_full_throughput(
    spec, vl2.rewired_vl2_topology, lo=base, hi=base + base // 2,
    runs=3, seed0=0)
gain = 100.0 * (best - base) / base
print(f"  rewired (same equipment) supports {best} ToRs "
      f"({best * spec.servers_per_tor} servers): +{gain:.0f}%")

# the designed path: same binary search, but each probe's wiring comes from
# the fleet optimizer (seeded from the recipe, so never certified worse)
designed = vl2.max_tors_at_full_throughput(
    spec, vl2.designed_vl2_topology, lo=best, hi=best + max(2, base // 2),
    runs=3, seed0=0)
dgain = 100.0 * (designed - base) / base
print(f"  designed (fleet search over the same equipment) supports "
      f"{designed} ToRs ({designed * spec.servers_per_tor} servers): "
      f"+{dgain:.0f}%")
print("  (the paper reports +43% at ~2400 servers, growing with scale;"
      " this demo runs the smallest instance)")
