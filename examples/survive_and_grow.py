"""A wiring's life after day one: survive failures, then grow.

Takes one random-regular fabric (the paper's high-throughput baseline)
and walks it through the lifecycle subsystem: first a certified
degradation sweep — independent link cuts vs correlated switch deaths,
each point a provable (lb, ub) throughput bracket plus the share of
demand still routable — then a budgeted Jellyfish-style expansion where
every growth step recables at most a handful of links and the certified
throughput floor never drops.

    PYTHONPATH=src python examples/survive_and_grow.py
"""
from repro.core.engine import CertifiedEngine
from repro.core.graphs import random_regular_graph
from repro.lifecycle import degradation_surface, plan_expansion

base = random_regular_graph(24, 5, seed=0, servers=3)
eng = CertifiedEngine(iters=200, tol=1e-3)
print(f"base: RRG(n={base.n}, r=5), {int(base.servers.sum())} servers")

print("\n-- degradation: certified throughput vs failure fraction --")
surface = degradation_surface({"rrg": base}, kinds=("links", "switches"),
                              fractions=(0.05, 0.15, 0.3), trials=8,
                              engine=eng, seed=0)
print(f"   ({surface.stats['executes']} plan executes, "
      f"{len(surface.stats['compile_keys'])} compile keys for the "
      "whole surface)")
print("   kind      fail%   lb median [q10..q90]   routable")
for p in surface.points:
    print(f"   {p.kind:<9} {100 * p.fraction:4.0f}    "
          f"{p.lb_med:.3f} [{p.lb_q10:.3f}..{p.lb_q90:.3f}]      "
          f"{100 * p.reachable_mean:3.0f}%")

print("\n-- expansion: add two 6-port switches per step, "
      "recable <= 4 links --")
growth = plan_expansion(base, [[6, 6], [6, 6], [6, 6]],
                        max_recabled_links=4, engine=eng, rounds=1,
                        fleet=4, elite=2, runs=2, seed=0)
for i, st in enumerate(growth.steps):
    print(f"   step {i}: {st.topo.n} switches, recabled {st.recabled}, "
          f"certified lb {st.lb:.3f} (ub {st.ub:.3f}, {st.chose})")
lbs = [st.lb for st in growth.steps]
assert all(b >= a for a, b in zip(lbs, lbs[1:]))
print("   certified floor is monotone: the attach preserves every "
      "previous flow,\n   so growth can only help — and the searcher "
      "spends the recabling budget\n   only where it buys throughput")
