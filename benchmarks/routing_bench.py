"""Routing-restricted throughput benchmark: the ideal-vs-ECMP-vs-KSP gap
per topology family, tracked across PRs.

The headline scenario the paper never measured: how much of the ideal
max-concurrent-flow capacity survives the routing operators actually
deploy.  For one representative of each family — random regular, biased
two-cluster, VL2 — this runs THREE engines over the same seeded
permutation instances: the certified ideal bracket, ECMP, and KSP(k).
Each engine solves the ENTIRE family sweep through one
``BatchPlan.execute`` (executes == 1 per sweep), a second fresh-traffic
round reuses the compiled programs (zero new XLA compiles — the shared
compile-key contract), and every row is checked against the ordering
lattice ``ecmp <= ksp(k) <= ideal`` before it is written.  Writes
``BENCH_routing.json`` (schema pinned in
``tests/test_bench_artifacts.py``).

    PYTHONPATH=src python -m benchmarks.routing_bench [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import rows_to_csv, write_bench_json
from repro.core import graphs, traffic, vl2
from repro.core import plan as plan_mod
from repro.core.engine import get_engine

# the BENCH_routing.json contract (tests/test_bench_artifacts.py pins it):
# per-family row keys, and the artifact-level extra block
ROUTING_ROW_KEYS = frozenset({
    "figure", "family", "n", "pattern", "runs", "k", "ideal_lb", "ideal_ub",
    "ecmp_lb", "ksp_lb", "ecmp_gap_pct", "ksp_gap_pct", "executes",
    "compile_keys", "wall_s",
})
ROUTING_EXTRA_KEYS = frozenset({"compile_keys", "last_plan", "k", "iters",
                                "round2_new_compiles"})

_PATTERN = "permutation"


def _families(smoke: bool) -> dict:
    if smoke:
        return {
            "rrg": graphs.random_regular_graph(12, 3, seed=0, servers=3),
            "two_cluster": graphs.biased_two_cluster_graph(
                [6] * 6, [4] * 6, cross_bias=0.6, seed=1, servers=2),
            "vl2": vl2.vl2_topology(
                vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=4), n_tor=4),
        }
    return {
        "rrg": graphs.random_regular_graph(24, 4, seed=0, servers=4),
        "two_cluster": graphs.biased_two_cluster_graph(
            [8] * 10, [5] * 10, cross_bias=0.5, seed=1, servers=3),
        "vl2": vl2.vl2_topology(
            vl2.VL2Spec(d_a=6, d_i=6, servers_per_tor=10), n_tor=8),
    }


def _gap_pct(lb: float, ub: float) -> float:
    return 100.0 * (ub - lb) / ub if ub > 0 else 0.0


def bench(scale: str = "small", engine=None) -> tuple[list[dict], dict]:
    """(rows, artifact-extra) of the routing-gap benchmark.  ``engine``
    is accepted for ``benchmarks.run`` uniformity and ignored — the
    comparison needs its own fixed trio (certified / ecmp / ksp)."""
    del engine
    smoke = scale == "smoke"
    runs = 2 if smoke else 3
    iters = 150 if smoke else 400
    k = 8
    fams = _families(smoke)

    # one flat instance pile: families x runs, solved per engine in ONE
    # solve_batch -> one BatchPlan.execute per engine for the whole sweep
    topos, dems, dems2 = [], [], []
    for fi, topo in enumerate(fams.values()):
        for r in range(runs):
            topos.append(topo)
            dems.append(traffic.make(_PATTERN, topo.servers,
                                     seed=100 * fi + r))
            dems2.append(traffic.make(_PATTERN, topo.servers,
                                      seed=100 * fi + r + 31))

    cert = get_engine("certified", iters=iters)
    ecmp = get_engine("ecmp", iters=iters)
    ksp = get_engine("ksp", iters=iters, k=k)

    t0 = time.time()
    res_c = cert.solve_batch(topos, dems)
    res_e = ecmp.solve_batch(topos, dems)
    res_k = ksp.solve_batch(topos, dems)
    wall = time.time() - t0

    plans = {"certified": cert.last_plan, "ecmp": ecmp.last_plan,
             "ksp": ksp.last_plan}
    # shared-compile-key contract, leg 1: the three engines plan the same
    # instances identically (same buckets, same chunk shapes)
    keys = {name: p.compile_keys for name, p in plans.items()}
    assert len(set(keys.values())) == 1, \
        f"engines disagreed on plan compile keys: {keys}"

    # leg 2: a second fresh-traffic round re-executes on the SAME compiled
    # programs — zero new routing-solver XLA compiles across rounds
    c1 = plan_mod.compile_cache_sizes()
    ecmp.solve_batch(topos, dems2)
    ksp.solve_batch(topos, dems2)
    c2 = plan_mod.compile_cache_sizes()
    round2_new = {kk: c2[kk] - c1[kk] for kk in c2
                  if kk.startswith("routing.")
                  and c1[kk] is not None and c2[kk] is not None}
    assert all(v == 0 for v in round2_new.values()), \
        f"fresh-traffic round recompiled the routing solvers: {round2_new}"

    rows = []
    for fi, (family, topo) in enumerate(fams.items()):
        lo = fi * runs
        rc = res_c[lo:lo + runs]
        re_ = res_e[lo:lo + runs]
        rk = res_k[lo:lo + runs]
        ideal_lb = float(np.mean([r.meta["lb"] for r in rc]))
        ideal_ub = float(np.mean([r.meta["ub"] for r in rc]))
        ecmp_lb = float(np.mean([r.throughput for r in re_]))
        ksp_lb = float(np.mean([r.throughput for r in rk]))
        # per-row lattice check against the certified ideal: every row
        # written to the artifact provably orders ecmp <= ksp <= ideal
        for c, e, kres in zip(rc, re_, rk):
            assert e.throughput <= kres.throughput * (1 + 1e-5), \
                (family, "ecmp > ksp")
            assert kres.throughput <= c.meta["ub"] * (1 + 1e-3), \
                (family, "ksp > ideal ub")
            assert c.meta["lb"] <= c.meta["ub"] * (1 + 1e-6), \
                (family, "ideal bracket inverted")
        rows.append({
            "figure": "routing", "family": family,
            "n": int(graphs.as_cap(topo).shape[0]), "pattern": _PATTERN,
            "runs": runs, "k": k,
            "ideal_lb": ideal_lb, "ideal_ub": ideal_ub,
            "ecmp_lb": ecmp_lb, "ksp_lb": ksp_lb,
            "ecmp_gap_pct": max(_gap_pct(e.throughput, c.meta["ub"])
                                for e, c in zip(re_, rc)),
            "ksp_gap_pct": max(_gap_pct(kres.throughput, c.meta["ub"])
                               for kres, c in zip(rk, rc)),
            # the whole family sweep is ONE execute per engine; wall_s is
            # the one-batch trio wall, identical across rows by design
            "executes": 1, "compile_keys": len(plans["ksp"].compile_keys),
            "wall_s": wall,
        })
    extra = {"compile_keys": [list(kk) for kk in plans["ksp"].compile_keys],
             "last_plan": plans["ksp"].as_dict(), "k": k, "iters": iters,
             "round2_new_compiles": round2_new}
    assert all(set(r) == ROUTING_ROW_KEYS for r in rows)
    assert set(extra) == ROUTING_EXTRA_KEYS
    return rows, extra


def run(scale: str = "small", engine=None) -> list[dict]:
    """``benchmarks.run`` entry point (rows only)."""
    return bench(scale, engine)[0]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI budget: 2 runs, 150 iters per family")
    args = ap.parse_args()
    t0 = time.time()
    rows, extra = bench("smoke" if args.smoke else args.scale)
    rows_to_csv(rows)
    worst = max(rows, key=lambda r: r["ecmp_gap_pct"])
    path = write_bench_json(
        "routing", rows, wall_s=time.time() - t0,
        headline=(f"ECMP leaves {worst['ecmp_gap_pct']:.1f}% of ideal "
                  f"throughput on the table ({worst['family']}); "
                  f"ksp(k={worst['k']}) trims that to "
                  f"{worst['ksp_gap_pct']:.1f}%"),
        extra=extra)
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
