"""Fig. 7: two line-speeds.  (a) several server splits x cross-cluster
connectivity (multiple near-ties); (b) higher line-speed and (c) more
high-speed links help at healthy cuts but not once the cross-cluster cut is
the bottleneck.

All three panels pool their sweeps into ONE ``run_sweeps`` call, so a
batching engine plans and executes the entire figure as a single
``BatchPlan`` (one bucket pass, chunked and sharded over the devices).
"""
from __future__ import annotations

from benchmarks.common import bracket_cols, rows_to_csv
from repro.core import heterogeneous as het
from repro.core.engine import run_sweeps


def run(scale: str = "small", engine="exact") -> list[dict]:
    runs = 3 if scale == "small" else 10
    biases = [0.2, 0.6, 1.0, 1.5]
    spec = het.TwoClassSpec(10, 18, 20, 6, 90, h_links=2, h_speed=4.0)

    items, labels = [], []

    # (a) server splits under mixed line-speeds
    for split in [(5, 2), (7, 1), (3, 3)]:
        if split[0] * spec.n_large + split[1] * spec.n_small \
                != spec.num_servers:
            continue
        items.append(het.cross_cluster_sweep_item(
            spec, biases, runs=runs, seed0=13,
            servers_on_large=split[0] * spec.n_large))
        labels.append(("fig7a", f"{split[0]}H,{split[1]}L"))

    # (b) line-speed of the high-speed links
    keys, sub = het.line_speed_sweep_items(spec, biases,
                                           h_speeds=[1.0, 4.0, 10.0],
                                           runs=runs, seed0=17)
    items.extend(sub)
    labels.extend(("fig7b", f"speed={k}") for k in keys)

    # (c) number of high-speed links
    keys, sub = het.line_speed_sweep_items(spec, biases, h_counts=[1, 3, 5],
                                           runs=runs, seed0=19)
    items.extend(sub)
    labels.extend(("fig7c", f"hlinks={k}") for k in keys)

    rows = []
    for (figure, config), pts in zip(labels, run_sweeps(items, engine)):
        for p in pts:
            rows.append({"figure": figure, "config": config, "bias": p.x,
                         "throughput": p.mean, "std": p.std,
                         **bracket_cols(p)})
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
