"""Fig. 7: two line-speeds.  (a) several server splits x cross-cluster
connectivity (multiple near-ties); (b) higher line-speed and (c) more
high-speed links help at healthy cuts but not once the cross-cluster cut is
the bottleneck."""
from __future__ import annotations

from benchmarks.common import rows_to_csv
from repro.core import heterogeneous as het


def run(scale: str = "small", engine="exact") -> list[dict]:
    runs = 3 if scale == "small" else 10
    biases = [0.2, 0.6, 1.0, 1.5]
    spec = het.TwoClassSpec(10, 18, 20, 6, 90, h_links=2, h_speed=4.0)
    rows = []

    # (a) server splits under mixed line-speeds
    for split in [(5, 2), (7, 1), (3, 3)]:
        if split[0] * spec.n_large + split[1] * spec.n_small \
                != spec.num_servers:
            continue
        pts = het.cross_cluster_sweep(
            spec, biases, runs=runs, seed0=13, engine=engine,
            servers_on_large=split[0] * spec.n_large)
        for p in pts:
            rows.append({"figure": "fig7a", "config": f"{split[0]}H,{split[1]}L",
                         "bias": p.x, "throughput": p.mean, "std": p.std})

    # (b) line-speed of the high-speed links
    out = het.line_speed_sweep(spec, biases, h_speeds=[1.0, 4.0, 10.0],
                               runs=runs, seed0=17, engine=engine)
    for speed, pts in out.items():
        for p in pts:
            rows.append({"figure": "fig7b", "config": f"speed={speed}",
                         "bias": p.x, "throughput": p.mean, "std": p.std})

    # (c) number of high-speed links
    out = het.line_speed_sweep(spec, biases, h_counts=[1, 3, 5],
                               runs=runs, seed0=19, engine=engine)
    for hc, pts in out.items():
        for p in pts:
            rows.append({"figure": "fig7c", "config": f"hlinks={hc}",
                         "bias": p.x, "throughput": p.mean, "std": p.std})
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
