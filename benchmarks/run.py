"""Run every paper-figure benchmark; print one CSV block per figure plus a
summary of derived headline numbers.  ``python -m benchmarks.run [--scale
small|paper] [--only fig5,fig11] [--engine exact|dual|dual-pallas|auto]
[--bucket pow2|mult128|<int>|none] [--tol 1e-4]``

``--bucket`` and ``--tol`` configure the dual engines' size-bucketed padded
batching and convergence-based early stopping; the summary reports how many
XLA programs the dual solver compiled across the whole run (one per bucket
shape on bucketing engines, one per distinct size otherwise)."""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

from benchmarks import (fabric_bench, fig1, fig2, fig3, fig4, fig5, fig6,
                        fig7, fig8, fig9_10, fig11, solver_bench)
from benchmarks.common import rows_to_csv
from repro.core import get_engine, mcf

MODULES = {
    "fig1": fig1, "fig2": fig2, "fig3": fig3, "fig4": fig4, "fig5": fig5,
    "fig6": fig6, "fig7": fig7, "fig8": fig8, "fig9_10": fig9_10,
    "fig11": fig11, "solver": solver_bench, "fabric": fabric_bench,
}


def headline(name: str, rows: list[dict]) -> str:
    try:
        if name == "fig1":
            best = max(r["frac_of_bound"] for r in rows)
            return f"RRG reaches {100*best:.1f}% of the universal bound"
        if name == "fig2":
            tail = rows[-1]
            return (f"N={tail['size']}: {100*tail['frac_of_bound']:.1f}% of "
                    "bound (gap shrinks with size)")
        if name == "fig3":
            return f"peak at x={rows[0]['peak_x']} (proportional)"
        if name == "fig4":
            return f"best beta={rows[0]['best_beta']}"
        if name == "fig5":
            lo = [r for r in rows if r["bias"] >= 0.6]
            return (f"plateau: >= {100*min(r['frac_of_peak'] for r in lo):.0f}%"
                    " of peak for bias >= 0.6")
        if name == "fig9_10":
            uni = [r for r in rows if r["config"] == "uniform"]
            g = sum(r["bound_gap"] for r in uni) / len(uni)
            return f"Eqn-1 bound within {100*(g-1):.1f}% (uniform speeds)"
        if name == "fig11":
            g = max(r["gain_pct"] for r in rows
                    if r["traffic"] == "permutation")
            return f"rewired VL2 supports +{g:.0f}% ToRs"
        if name == "solver":
            g = max(abs(r["gap_pct"]) for r in rows)
            return f"dual solver within {g:.2f}% of exact LP"
        if name == "fabric":
            g = max(r["gain_x"] for r in rows)
            return f"paper-rule fabric up to {g:.1f}x collective bandwidth"
    except Exception as exc:   # noqa: BLE001
        print(f"headline for {name} failed: {exc!r}", file=sys.stderr)
        traceback.print_exc(file=sys.stderr)
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument("--only", default=None)
    ap.add_argument("--engine", default="exact",
                    choices=["exact", "dual", "dual-pallas", "auto"])
    ap.add_argument("--bucket", default="pow2",
                    help="dual-engine size-bucket mode: pow2|mult128|<int>|"
                         "none (none = group by exact size)")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="dual-engine early-stop relative-improvement "
                         "tolerance per check window (0 = fixed iters)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; known: {list(MODULES)}")
    bucket = args.bucket if not args.bucket.isdigit() else int(args.bucket)
    if args.engine in ("dual", "dual-pallas", "auto"):
        # instantiate so --bucket/--tol reach the solver; drivers accept
        # engine instances via as_engine
        engine = get_engine(args.engine, bucket=bucket, tol=args.tol)
    else:
        engine = args.engine
    compiles0 = mcf.compile_cache_sizes()
    summary = []
    for name in names:
        fn = MODULES[name].run
        kw = ({"engine": engine}
              if "engine" in inspect.signature(fn).parameters else {})
        if not kw and args.engine != "exact":
            print(f"note: {name} does not take --engine; running it with "
                  "its built-in exact solver", file=sys.stderr)
        t0 = time.time()
        rows = fn(args.scale, **kw)
        dt = time.time() - t0
        print(f"\n=== {name} ({dt:.1f}s) ===", flush=True)
        rows_to_csv(rows)
        summary.append((name, dt, headline(name, rows)))
    print("\n=== summary ===")
    print("name,seconds,headline")
    for name, dt, h in summary:
        print(f"{name},{dt:.1f},{h}")
    compiles = mcf.compile_cache_sizes()

    def delta(key: str):
        a, b = compiles0[key], compiles[key]
        return "n/a" if a is None or b is None else b - a

    print(f"dual-solver XLA compiles: batch={delta('solve_batch')} "
          f"single={delta('solve')} (bucket={bucket}, tol={args.tol})")


if __name__ == "__main__":
    main()
