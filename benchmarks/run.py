"""Run every paper-figure benchmark; print one CSV block per figure plus a
summary of derived headline numbers.  ``python -m benchmarks.run [--scale
small|paper] [--only fig5,fig11] [--engine <name from engine.ENGINES>]
[--bucket pow2|mult128|<int>|none] [--tol 1e-4] [--devices N]
[--max-lanes N] [--out-dir DIR]``

``--bucket``/``--tol`` configure the dual engines' size-bucketed padded
batching and convergence-based early stopping; ``--devices``/``--max-lanes``
configure the ``BatchPlan`` execution core (how many local devices each
chunk's batch axis is sharded over, and the per-chunk lane budget).  The
summary reports how many XLA programs the dual solver compiled across the
whole run (one per (bucket, chunk-shape) on planning engines).

Besides the stdout CSV, every figure writes a machine-readable
``BENCH_<name>.json`` artifact (rows + headline + wall time + plan/compile
stats) under ``--out-dir`` so the perf trajectory is tracked across PRs;
CI uploads them from the benchmark smoke step.  With a bracket engine
(``--engine certified``) sweep-driven figures add a per-row ``gap`` column
(worst relative bracket width of the point) and the artifact carries the
figure-level ``max_gap`` headline."""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

from benchmarks import (adversarial_bench, design_bench, fabric_bench, fig1,
                        fig2, fig3, fig4, fig5, fig6, fig7, fig8, fig9_10,
                        fig11, lifecycle_bench, routing_bench, scale_bench,
                        solver_bench)
from benchmarks.common import (bench_extra, max_bracket_gap, rows_to_csv,
                               write_bench_json)
from repro.core import engine as engine_mod
from repro.core import get_engine
from repro.core import plan as plan_mod

MODULES = {
    "fig1": fig1, "fig2": fig2, "fig3": fig3, "fig4": fig4, "fig5": fig5,
    "fig6": fig6, "fig7": fig7, "fig8": fig8, "fig9_10": fig9_10,
    "fig11": fig11, "solver": solver_bench, "fabric": fabric_bench,
    "design": design_bench, "lifecycle": lifecycle_bench,
    "scale": scale_bench, "adversarial": adversarial_bench,
    "routing": routing_bench,
}


def headline(name: str, rows: list[dict]) -> str:
    try:
        if name == "fig1":
            best = max(r["frac_of_bound"] for r in rows)
            return f"RRG reaches {100*best:.1f}% of the universal bound"
        if name == "fig2":
            tail = rows[-1]
            return (f"N={tail['size']}: {100*tail['frac_of_bound']:.1f}% of "
                    "bound (gap shrinks with size)")
        if name == "fig3":
            return f"peak at x={rows[0]['peak_x']} (proportional)"
        if name == "fig4":
            return f"best beta={rows[0]['best_beta']}"
        if name == "fig5":
            lo = [r for r in rows if r["bias"] >= 0.6]
            return (f"plateau: >= {100*min(r['frac_of_peak'] for r in lo):.0f}%"
                    " of peak for bias >= 0.6")
        if name == "fig9_10":
            uni = [r for r in rows if r["config"] == "uniform"]
            g = sum(r["bound_gap"] for r in uni) / len(uni)
            return f"Eqn-1 bound within {100*(g-1):.1f}% (uniform speeds)"
        if name == "fig11":
            g = max(r["gain_pct"] for r in rows
                    if r["traffic"] == "permutation")
            return f"rewired VL2 supports +{g:.0f}% ToRs"
        if name == "solver":
            g = max(abs(r["gap_pct"]) for r in rows)
            return f"dual solver within {g:.2f}% of exact LP"
        if name == "design":
            g = max(r["design_gain_pct"] for r in rows)
            return f"fleet search beats recipe by up to +{g:.1f}% (cert. lb)"
        if name == "adversarial":
            g = max(r["uniform_gap_pct"] for r in rows)
            worst = max(rows, key=lambda r: r["uniform_gap_pct"])["family"]
            return (f"worst-case TM cuts certified throughput by "
                    f"{g:.1f}% ({worst})")
        if name == "routing":
            worst = max(rows, key=lambda r: r["ecmp_gap_pct"])
            return (f"ECMP gap {worst['ecmp_gap_pct']:.1f}% of ideal "
                    f"({worst['family']}); ksp(k={worst['k']}) trims it "
                    f"to {worst['ksp_gap_pct']:.1f}%")
        if name == "fabric":
            g = max(r["gain_x"] for r in rows)
            return f"paper-rule fabric up to {g:.1f}x collective bandwidth"
        if name == "lifecycle":
            hi = max(r["fraction"] for r in rows)
            reach = min(r["reachable_mean"] for r in rows
                        if r["fraction"] == hi and r["kind"] == "links")
            return (f"at {100 * hi:.0f}% link cuts {100 * reach:.0f}% of "
                    "demand stays routable (certified curves)")
        if name == "scale":
            fr = {b: max((r["n"] for r in rows
                          if r["section"] == "frontier"
                          and r["backend"] == b and r["ok"]), default=0)
                  for b in ("squaring", "blocked-fw")}
            walls = {r["label"]: r["wall_s"] for r in rows
                     if r["section"] == "aot" and r["wall_s"]}
            h = (f"blocked-fw APSP frontier N={fr['blocked-fw']} "
                 f"({fr['blocked-fw'] // max(fr['squaring'], 1)}x squaring)")
            if "cold" in walls and "warm" in walls:
                pct = 100 * walls["warm"] / walls["cold"]
                h += f"; warm start {pct:.0f}% of cold"
            return h
    except Exception as exc:   # noqa: BLE001
        print(f"headline for {name} failed: {exc!r}", file=sys.stderr)
        traceback.print_exc(file=sys.stderr)
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument("--only", default=None)
    # derived from the registry so new engines never drift out of the CLI
    ap.add_argument("--engine", default="exact",
                    choices=sorted(engine_mod.ENGINES))
    ap.add_argument("--bucket", default="pow2",
                    help="dual-engine size-bucket mode: pow2|mult128|<int>|"
                         "none (none = group by exact size)")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="dual-engine early-stop relative-improvement "
                         "tolerance per check window (0 = fixed iters)")
    ap.add_argument("--devices", type=int, default=None,
                    help="local devices each BatchPlan chunk is sharded "
                         "over (default: all)")
    ap.add_argument("--max-lanes", type=int, default=None,
                    help="BatchPlan lane budget: max batch rows per chunk "
                         "(default: whole bucket in one launch)")
    ap.add_argument("--out-dir", default=None,
                    help="directory for BENCH_<name>.json artifacts "
                         "(default: $BENCH_OUT_DIR or bench_artifacts)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; known: {list(MODULES)}")
    bucket = args.bucket if not args.bucket.isdigit() else int(args.bucket)
    if args.engine == "exact":
        engine = args.engine
    else:
        # instantiate so --bucket/--tol/--devices/--max-lanes reach the
        # planner; drivers accept engine instances via as_engine
        engine = get_engine(args.engine, bucket=bucket, tol=args.tol,
                            devices=args.devices, max_lanes=args.max_lanes)
    run_compiles0 = plan_mod.compile_cache_sizes()
    summary = []
    max_gap = None
    for name in names:
        fn = MODULES[name].run
        kw = ({"engine": engine}
              if "engine" in inspect.signature(fn).parameters else {})
        if not kw and args.engine != "exact":
            print(f"note: {name} does not take --engine; running it with "
                  "its built-in exact solver", file=sys.stderr)
        compiles0 = plan_mod.compile_cache_sizes()
        plan0 = getattr(engine, "last_plan", None)
        t0 = time.time()
        rows = fn(args.scale, **kw)
        dt = time.time() - t0
        print(f"\n=== {name} ({dt:.1f}s) ===", flush=True)
        rows_to_csv(rows)
        h = headline(name, rows)
        summary.append((name, dt, h))
        compiles = plan_mod.compile_cache_sizes()
        # only report a plan this figure actually produced (identity check:
        # each solve_batch makes a fresh PlanStats).  "last_plan", not
        # "plan": a figure driving several solve_batch calls (e.g. fig3's
        # one sweep per spec) reports its final plan here, while "compiles"
        # spans ALL of the figure's solves.
        plan1 = getattr(engine, "last_plan", None)
        # bracket engines annotate sweep rows with their per-point gap;
        # the figure's worst gap is the artifact's certification headline
        fig_gap = max_bracket_gap(rows)
        if fig_gap is not None:
            max_gap = fig_gap if max_gap is None else max(max_gap, fig_gap)
        stats = bench_extra(
            scale=args.scale, engine=args.engine,
            compiles={k: (None if compiles0[k] is None
                          or compiles[k] is None
                          else compiles[k] - compiles0[k])
                      for k in compiles},
            last_plan=(plan1.as_dict()
                       if plan1 is not None and plan1 is not plan0
                       else None))
        stats["max_gap"] = fig_gap
        path = write_bench_json(name, rows, headline=h, wall_s=dt,
                                extra=stats, out_dir=args.out_dir)
        print(f"wrote {path}", file=sys.stderr)
    print("\n=== summary ===")
    print("name,seconds,headline")
    for name, dt, h in summary:
        print(f"{name},{dt:.1f},{h}")
    if max_gap is not None:
        print(f"certified max bracket gap: {100 * max_gap:.2f}%")
    compiles = plan_mod.compile_cache_sizes()

    def delta(key: str):
        a, b = run_compiles0[key], compiles[key]
        return "n/a" if a is None or b is None else b - a

    deltas = " ".join(f"{k}={delta(k)}" for k in sorted(compiles))
    print(f"solver XLA compiles: {deltas} (bucket={bucket}, tol={args.tol}, "
          f"devices={args.devices or 'all'}, "
          f"max_lanes={args.max_lanes or 'unbounded'})")


if __name__ == "__main__":
    main()
