"""Run every paper-figure benchmark; print one CSV block per figure plus a
summary of derived headline numbers.  ``python -m benchmarks.run [--scale
small|paper] [--only fig5,fig11]``"""
from __future__ import annotations

import argparse
import time

from benchmarks import (fabric_bench, fig1, fig2, fig3, fig4, fig5, fig6,
                        fig7, fig8, fig9_10, fig11, solver_bench)
from benchmarks.common import rows_to_csv

MODULES = {
    "fig1": fig1, "fig2": fig2, "fig3": fig3, "fig4": fig4, "fig5": fig5,
    "fig6": fig6, "fig7": fig7, "fig8": fig8, "fig9_10": fig9_10,
    "fig11": fig11, "solver": solver_bench, "fabric": fabric_bench,
}


def headline(name: str, rows: list[dict]) -> str:
    try:
        if name == "fig1":
            best = max(r["frac_of_bound"] for r in rows)
            return f"RRG reaches {100*best:.1f}% of the universal bound"
        if name == "fig2":
            tail = rows[-1]
            return (f"N={tail['size']}: {100*tail['frac_of_bound']:.1f}% of "
                    "bound (gap shrinks with size)")
        if name == "fig3":
            return f"peak at x={rows[0]['peak_x']} (proportional)"
        if name == "fig4":
            return f"best beta={rows[0]['best_beta']}"
        if name == "fig5":
            lo = [r for r in rows if r["bias"] >= 0.6]
            return (f"plateau: >= {100*min(r['frac_of_peak'] for r in lo):.0f}%"
                    " of peak for bias >= 0.6")
        if name == "fig9_10":
            uni = [r for r in rows if r["config"] == "uniform"]
            g = sum(r["bound_gap"] for r in uni) / len(uni)
            return f"Eqn-1 bound within {100*(g-1):.1f}% (uniform speeds)"
        if name == "fig11":
            g = max(r["gain_pct"] for r in rows
                    if r["traffic"] == "permutation")
            return f"rewired VL2 supports +{g:.0f}% ToRs"
        if name == "solver":
            g = max(abs(r["gap_pct"]) for r in rows)
            return f"dual solver within {g:.2f}% of exact LP"
        if name == "fabric":
            g = max(r["gain_x"] for r in rows)
            return f"paper-rule fabric up to {g:.1f}x collective bandwidth"
    except Exception:   # noqa: BLE001
        pass
    return ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    summary = []
    for name in names:
        t0 = time.time()
        rows = MODULES[name].run(args.scale)
        dt = time.time() - t0
        print(f"\n=== {name} ({dt:.1f}s) ===", flush=True)
        rows_to_csv(rows)
        summary.append((name, dt, headline(name, rows)))
    print("\n=== summary ===")
    print("name,seconds,headline")
    for name, dt, h in summary:
        print(f"{name},{dt:.1f},{h}")


if __name__ == "__main__":
    main()
