"""Figs. 9-10: the analytic heterogeneous throughput bound (Eqn. 1) vs the
observed throughput along a cross-cluster sweep (tight for uniform
line-speed, looser for mixed), and the C-bar* threshold below which
throughput MUST drop (Eqn. 2 / Fig. 10)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import rows_to_csv
from repro.core import bounds, get_engine, heterogeneous as het, lp, traffic


def run(scale: str = "small") -> list[dict]:
    eng = get_engine("exact")    # Eqn-1 gap needs the exact optimum
    runs = 3 if scale == "small" else 10
    biases = [0.1, 0.2, 0.4, 0.7, 1.0, 1.4]
    rows = []
    for name, spec in {
        "uniform": het.TwoClassSpec(10, 18, 20, 6, 90),
        "mixed": het.TwoClassSpec(10, 18, 20, 6, 90, h_links=2, h_speed=4.0),
    }.items():
        series = []
        for bias in biases:
            ths, ubs = [], []
            for rr in range(runs):
                topo = het.build_two_class(
                    spec, spec.proportional_large_servers, bias, 37 * rr)
                dem = traffic.random_permutation(topo.servers, 37 * rr + 5)
                th = eng.solve(topo, dem).throughput
                mask = topo.labels == 1
                cbar = topo.cut_capacity(mask)
                n1 = int(topo.servers[mask].sum())
                n2 = int(topo.servers[~mask].sum())
                ub = bounds.het_throughput_upper_bound(
                    topo.total_capacity, cbar, lp.aspl_hops(topo, dem),
                    n1, n2)
                ths.append(th)
                ubs.append(ub)
            series.append((bias, float(np.mean(ths)), float(np.mean(ubs)),
                           cbar))
        t_star = max(t for _, t, _, _ in series)
        cbar_star = bounds.cut_threshold(t_star, n1, n2)
        for bias, th, ub, cbar in series:
            rows.append({
                "figure": "fig9_10", "config": name, "bias": bias,
                "throughput": th, "eqn1_bound": ub,
                "bound_gap": ub / th if th else float("inf"),
                "cut_capacity": cbar, "cbar_star": cbar_star,
                "below_threshold": cbar < cbar_star,
                "t_star": t_star,
            })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
