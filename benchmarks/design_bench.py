"""Designer benchmark: fleet search over wirings, tracked across PRs.

Runs the batched stochastic optimizer (``repro.design``) on the Fig. 11
small-scale VL2 equipment pool and on a two-class heterogeneous pool, and
records what the search bought over the paper's hand-coded recipes —
best-found vs recipe certified lower bound — plus what it cost: rounds,
fleet size, ``BatchPlan`` executes (exactly one per search round), the
distinct XLA compile keys, and wall time.  Writes ``BENCH_design.json``
next to the other artifacts (schema pinned in
``tests/test_bench_artifacts.py``).

Two producers write that filename: THIS standalone entry point (what CI
runs) attaches the design-specific extra block (``DESIGN_EXTRA_KEYS``:
compile-key list, rounds, fleet, last plan), while ``benchmarks.run
--only design`` wraps the same rows in the generic per-figure stats
block (scale/engine/compiles/last_plan/max_gap) like every other figure.
The ROWS are identical either way — per-space counters (executes,
compile_keys, rounds, fleet) live in each row precisely so consumers can
rely on them regardless of producer.

    PYTHONPATH=src python -m benchmarks.design_bench [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import rows_to_csv, write_bench_json
from repro.core import heterogeneous as het, vl2
from repro.core.engine import DualEngine
from repro.design import TwoClassSpace, VL2Space, optimize

# the BENCH_design.json contract (tests/test_bench_artifacts.py pins it):
# per-search-space row keys, and the artifact-level extra block
DESIGN_ROW_KEYS = frozenset({
    "figure", "space", "rounds", "fleet", "elite", "runs", "executes",
    "search_executes", "compile_keys", "instances_per_round", "recipe_lb",
    "best_lb", "best_ub", "design_gain_pct", "wall_s",
})
DESIGN_EXTRA_KEYS = frozenset({"compile_keys", "last_plan", "rounds",
                               "fleet"})


def _search_row(label: str, space, moves, *, rounds, fleet, elite, runs,
                seed, engine) -> tuple[dict, dict]:
    t0 = time.time()
    result = optimize(space, engine=engine, moves=moves, rounds=rounds,
                      fleet=fleet, elite=elite, runs=runs, seed=seed)
    wall = time.time() - t0
    s = result.stats
    recipe_lb = result.reference.lb
    best_lb = result.best.lb
    row = {
        "figure": "design", "space": label, "rounds": s["rounds"],
        "fleet": fleet, "elite": elite, "runs": runs,
        "executes": s["executes"],
        "search_executes": s["search_executes"],
        "compile_keys": len(s["compile_keys"]),
        "instances_per_round": s["instances_per_round"],
        "recipe_lb": recipe_lb, "best_lb": best_lb,
        "best_ub": result.best.ub,
        "design_gain_pct": 100.0 * (best_lb / recipe_lb - 1)
        if recipe_lb > 0 else 0.0,
        "wall_s": wall,
    }
    extra = {"compile_keys": [list(k) for k in s["compile_keys"]],
             "last_plan": s["last_plan"]}
    return row, extra


def bench(scale: str = "small", engine=None) -> tuple[list[dict], dict]:
    """(rows, artifact-extra) of the designer benchmark.  ``engine`` is
    accepted for ``benchmarks.run`` uniformity; non-planning engines fall
    back to the designer's default cheap-ranking dual engine."""
    smoke = scale == "smoke"
    if engine is None or not hasattr(engine, "plan"):
        engine = DualEngine(iters=60 if smoke else 250, tol=1e-3)
    budget = dict(rounds=1, fleet=4, elite=2, runs=2) if smoke else \
        dict(rounds=3, fleet=8, elite=3, runs=2)
    spec = vl2.VL2Spec(d_a=4 if smoke else 6, d_i=4 if smoke else 6,
                       servers_per_tor=4 if smoke else 20)
    vl2_row, vl2_extra = _search_row(
        "vl2", VL2Space(spec, spec.n_tor_full), ("swap",), seed=0,
        engine=engine, **budget)
    tspec = het.TwoClassSpec(n_large=4, k_large=12, n_small=8, k_small=5,
                             num_servers=30) if smoke else \
        het.TwoClassSpec(n_large=10, k_large=18, n_small=20, k_small=6,
                         num_servers=90)
    het_row, _ = _search_row(
        "two_class", TwoClassSpace(tspec), ("swap", "servers", "bias"),
        seed=0, engine=engine, **budget)
    rows = [vl2_row, het_row]
    # the optimizer can never report a wiring certified worse than the
    # recipe it started from — enforced here so the artifact is trustable
    assert all(r["best_lb"] >= r["recipe_lb"] - 1e-6 for r in rows), \
        "designer regressed below its recipe reference"
    extra = {**vl2_extra, "rounds": budget["rounds"],
             "fleet": budget["fleet"]}
    assert all(set(r) == DESIGN_ROW_KEYS for r in rows)
    assert set(extra) == DESIGN_EXTRA_KEYS
    return rows, extra


def run(scale: str = "small", engine=None) -> list[dict]:
    """``benchmarks.run`` entry point (rows only)."""
    return bench(scale, engine)[0]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI budget: 1 round, fleet of 4, 60 iters")
    args = ap.parse_args()
    t0 = time.time()
    rows, extra = bench("smoke" if args.smoke else args.scale)
    rows_to_csv(rows)
    path = write_bench_json("design", rows, wall_s=time.time() - t0,
                            headline=f"designed vs recipe: "
                            f"+{max(r['design_gain_pct'] for r in rows):.1f}%"
                            " certified lb",
                            extra=extra)
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
