"""Fig. 5: throughput vs cross-cluster connectivity — a wide plateau at the
peak, collapsing only at starved cuts, across port ratios / counts /
oversubscription."""
from __future__ import annotations

from benchmarks.common import bracket_cols, rows_to_csv
from repro.core import heterogeneous as het


def _specs(scale: str):
    if scale == "small":
        return {
            "a_ports": het.TwoClassSpec(10, 18, 20, 6, 90),
            "b_counts": het.TwoClassSpec(10, 18, 30, 6, 90),
            "c_servers": het.TwoClassSpec(10, 18, 20, 6, 120),
        }
    return {
        "a_ports": het.TwoClassSpec(20, 30, 40, 10, 300),
        "b_counts": het.TwoClassSpec(20, 30, 20, 10, 300),
        "c_servers": het.TwoClassSpec(20, 30, 40, 10, 500),
    }


def run(scale: str = "small", engine="exact") -> list[dict]:
    biases = [0.1, 0.3, 0.6, 1.0, 1.4, 1.8]
    runs = 3 if scale == "small" else 10
    rows = []
    for name, spec in _specs(scale).items():
        # one declarative sweep per config: every (bias x run) instance goes
        # through a single solve_batch (one vmapped program on dual engines)
        pts = het.cross_cluster_sweep(spec, biases, runs=runs, seed0=3,
                                      engine=engine)
        peak = max(p.mean for p in pts)
        for p in pts:
            rows.append({"figure": "fig5", "config": name, "bias": p.x,
                         "throughput": p.mean, "std": p.std,
                         "frac_of_peak": p.mean / peak,
                         **bracket_cols(p)})
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
