"""Adversarial-traffic benchmark: uniform vs worst-case throughput per
family, tracked across PRs.

Runs the differentiable worst-TM search (``repro.core.adversarial``) on
one representative of each topology family — random regular, biased
two-cluster (where sampled traffic is most misleading: the weak cross-
cluster cut hides behind any permutation that mostly stays in-cluster),
and VL2 — and records the certified uniform-vs-adversarial throughput
gap plus what the search cost: candidates per round, ``BatchPlan``
executes (exactly ``1 + rounds``: one per search round plus one
certification), and the distinct XLA compile keys (one — every round and
the certification ride the round-one plan).  Writes
``BENCH_adversarial.json`` next to the other artifacts (schema pinned in
``tests/test_bench_artifacts.py``).

    PYTHONPATH=src python -m benchmarks.adversarial_bench [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import rows_to_csv, write_bench_json
from repro.core import graphs, vl2
from repro.core.adversarial import find_worst_tm

# the BENCH_adversarial.json contract (tests/test_bench_artifacts.py pins
# it): per-family row keys, and the artifact-level extra block
ADVERSARIAL_ROW_KEYS = frozenset({
    "figure", "family", "n", "rounds", "candidates", "executes",
    "search_executes", "compile_keys", "baseline_lb", "baseline_ub",
    "adversarial_lb", "adversarial_ub", "uniform_gap_pct", "wall_s",
})
ADVERSARIAL_EXTRA_KEYS = frozenset({"compile_keys", "last_plan", "rounds",
                                    "candidates"})


def _families(smoke: bool) -> dict:
    if smoke:
        return {
            "rrg": graphs.random_regular_graph(12, 3, seed=0, servers=3),
            "two_cluster": graphs.biased_two_cluster_graph(
                [6] * 6, [4] * 6, cross_bias=0.6, seed=1, servers=2),
            "vl2": vl2.vl2_topology(
                vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=4), n_tor=4),
        }
    return {
        "rrg": graphs.random_regular_graph(24, 4, seed=0, servers=4),
        "two_cluster": graphs.biased_two_cluster_graph(
            [8] * 10, [5] * 10, cross_bias=0.5, seed=1, servers=3),
        "vl2": vl2.vl2_topology(
            vl2.VL2Spec(d_a=6, d_i=6, servers_per_tor=10), n_tor=8),
    }


def bench(scale: str = "small", engine=None) -> tuple[list[dict], dict]:
    """(rows, artifact-extra) of the adversarial-traffic benchmark.
    ``engine`` is accepted for ``benchmarks.run`` uniformity and ignored
    — the search drives its own dual-demgrad/primal plans."""
    del engine
    smoke = scale == "smoke"
    budget = (dict(rounds=2, candidates=4, iters=150) if smoke
              else dict(rounds=4, candidates=8, iters=300))
    rows, extra = [], None
    for family, topo in _families(smoke).items():
        t0 = time.time()
        res = find_worst_tm(topo, seed=0, **budget)
        s = res.stats
        rows.append({
            "figure": "adversarial", "family": family,
            "n": int(len(res.tm)), "rounds": s["rounds"],
            "candidates": s["candidates"], "executes": s["executes"],
            "search_executes": s["search_executes"],
            "compile_keys": len(s["compile_keys"]),
            "baseline_lb": res.baseline_lb, "baseline_ub": res.baseline_ub,
            "adversarial_lb": res.lb, "adversarial_ub": res.ub,
            "uniform_gap_pct": res.uniform_gap_pct,
            "wall_s": time.time() - t0,
        })
        if extra is None:
            extra = {"compile_keys": [list(k) for k in s["compile_keys"]],
                     "last_plan": s["last_plan"],
                     "rounds": budget["rounds"],
                     "candidates": budget["candidates"]}
    # the execute contract: one BatchPlan.execute per search round plus
    # ONE certification, all on round one's compile keys
    assert all(r["executes"] == 1 + r["rounds"] for r in rows), \
        "adversarial search broke the one-execute-per-round contract"
    assert all(r["compile_keys"] == 1 for r in rows), \
        "adversarial search leaked extra plan compile keys"
    # the acceptance claim: on the biased two-cluster family the found TM's
    # certified throughput sits strictly below the uniform baseline's
    tc = next(r for r in rows if r["family"] == "two_cluster")
    assert tc["adversarial_ub"] < tc["baseline_ub"], \
        "adversarial TM not certified below the uniform baseline"
    assert all(set(r) == ADVERSARIAL_ROW_KEYS for r in rows)
    assert set(extra) == ADVERSARIAL_EXTRA_KEYS
    return rows, extra


def run(scale: str = "small", engine=None) -> list[dict]:
    """``benchmarks.run`` entry point (rows only)."""
    return bench(scale, engine)[0]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI budget: 2 rounds, 4 candidates, 150 iters")
    args = ap.parse_args()
    t0 = time.time()
    rows, extra = bench("smoke" if args.smoke else args.scale)
    rows_to_csv(rows)
    path = write_bench_json(
        "adversarial", rows, wall_s=time.time() - t0,
        headline="uniform->adversarial certified gap: "
        f"{max(r['uniform_gap_pct'] for r in rows):.1f}% worst family",
        extra=extra)
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
