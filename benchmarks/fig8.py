"""Fig. 8: decompose throughput into C*U / (f*<D>*AS) along a cross-cluster
sweep — utilisation (bottleneck location) explains throughput best; also
reports per-link-class utilisation showing the bottleneck moving to the cut."""
from __future__ import annotations

from benchmarks.common import rows_to_csv
from repro.core import decompose, heterogeneous as het, lp, traffic


def run(scale: str = "small") -> list[dict]:
    spec = het.TwoClassSpec(10, 18, 20, 6, 120)
    biases = [0.1, 0.3, 0.6, 1.0, 1.5]
    runs = 3 if scale == "small" else 10
    rows = []
    per_bias = []
    for bias in biases:
        vals = []
        for rr in range(runs):
            topo = het.build_two_class(
                spec, spec.proportional_large_servers, bias, seed=rr * 97)
            dem = traffic.random_permutation(topo.servers, seed=rr * 97 + 1)
            res = lp.max_concurrent_flow(topo, dem)
            d = decompose.decompose(topo, dem, res)
            util_cls = decompose.utilization_by_class(res, topo.labels)
            vals.append((d, util_cls))
        d0, u0 = vals[0]
        mean = lambda f: sum(f(d) for d, _ in vals) / len(vals)
        per_bias.append({
            "bias": bias,
            "throughput": mean(lambda d: d.throughput),
            "utilization": mean(lambda d: d.utilization),
            "inv_aspl": mean(lambda d: 1.0 / d.aspl),
            "inv_stretch": mean(lambda d: 1.0 / d.stretch),
            "util_cross": sum(u.get((0, 1), 0) for _, u in vals) / len(vals),
            "util_small": sum(u.get((0, 0), 0) for _, u in vals) / len(vals),
            "util_large": sum(u.get((1, 1), 0) for _, u in vals) / len(vals),
        })
    # normalise each factor to its value at peak throughput (paper style)
    peak = max(per_bias, key=lambda r: r["throughput"])
    for r in per_bias:
        rows.append({
            "figure": "fig8", "bias": r["bias"],
            "T_norm": r["throughput"] / peak["throughput"],
            "U_norm": r["utilization"] / peak["utilization"],
            "invD_norm": r["inv_aspl"] / peak["inv_aspl"],
            "invAS_norm": r["inv_stretch"] / peak["inv_stretch"],
            "util_cross": r["util_cross"], "util_small": r["util_small"],
            "util_large": r["util_large"],
        })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
