"""Fig. 8: decompose throughput into C*U / (f*<D>*AS) along a cross-cluster
sweep — utilisation (bottleneck location) explains throughput best; also
reports per-link-class utilisation showing the bottleneck moving to the cut."""
from __future__ import annotations

import numpy as np

from benchmarks.common import rows_to_csv
from repro.core import decompose, heterogeneous as het, lp, traffic


def run(scale: str = "small") -> list[dict]:
    spec = het.TwoClassSpec(10, 18, 20, 6, 120)
    biases = [0.1, 0.3, 0.6, 1.0, 1.5]
    runs = 3 if scale == "small" else 10
    rows = []
    per_bias = []
    for bias in biases:
        decomps, utils = [], []
        for rr in range(runs):
            topo = het.build_two_class(
                spec, spec.proportional_large_servers, bias, seed=rr * 97)
            dem = traffic.random_permutation(topo.servers, seed=rr * 97 + 1)
            res = lp.max_concurrent_flow(topo, dem)
            decomps.append(decompose.decompose(topo, dem, res))
            utils.append(decompose.utilization_by_class(res, topo.labels))
        per_bias.append({
            "bias": bias,
            "throughput": np.mean([d.throughput for d in decomps]),
            "utilization": np.mean([d.utilization for d in decomps]),
            "inv_aspl": np.mean([1.0 / d.aspl for d in decomps]),
            "inv_stretch": np.mean([1.0 / d.stretch for d in decomps]),
            "util_cross": np.mean([u.get((0, 1), 0) for u in utils]),
            "util_small": np.mean([u.get((0, 0), 0) for u in utils]),
            "util_large": np.mean([u.get((1, 1), 0) for u in utils]),
        })
    # normalise each factor to its value at peak throughput (paper style)
    peak = max(per_bias, key=lambda r: r["throughput"])
    for r in per_bias:
        rows.append({
            "figure": "fig8", "bias": r["bias"],
            "T_norm": r["throughput"] / peak["throughput"],
            "U_norm": r["utilization"] / peak["utilization"],
            "invD_norm": r["inv_aspl"] / peak["inv_aspl"],
            "invAS_norm": r["inv_stretch"] / peak["inv_stretch"],
            "util_cross": r["util_cross"], "util_small": r["util_small"],
            "util_large": r["util_large"],
        })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
