"""Fig. 3: distributing servers across two switch classes — proportional
(x=1) is optimal regardless of (a) port ratios, (b) switch counts,
(c) oversubscription."""
from __future__ import annotations

from benchmarks.common import rows_to_csv
from repro.core import heterogeneous as het


def _specs(scale: str):
    if scale == "small":
        return {
            "a_3:1": het.TwoClassSpec(10, 18, 20, 6, 90),
            "a_2:1": het.TwoClassSpec(10, 18, 20, 9, 90),
            "b_more_small": het.TwoClassSpec(10, 18, 30, 6, 90),
            "c_oversub": het.TwoClassSpec(10, 18, 20, 6, 120),
        }
    return {   # paper sizes: 20 large x30p, 40 small (Fig 3a)
        "a_3:1": het.TwoClassSpec(20, 30, 40, 10, 300),
        "a_2:1": het.TwoClassSpec(20, 30, 40, 15, 300),
        "a_3:2": het.TwoClassSpec(20, 30, 40, 20, 300),
        "c_480": het.TwoClassSpec(20, 30, 30, 20, 480),
    }


def run(scale: str = "small", engine="exact") -> list[dict]:
    xs = [0.4, 0.7, 1.0, 1.3, 1.6]
    runs = 3 if scale == "small" else 10
    rows = []
    for name, spec in _specs(scale).items():
        pts = het.server_distribution_sweep(spec, xs, runs=runs, seed0=7,
                                            engine=engine)
        peak_x = max(pts, key=lambda p: p.mean).x
        for p in pts:
            rows.append({"figure": "fig3", "config": name, "x": p.x,
                         "throughput": p.mean, "std": p.std,
                         "peak_x": peak_x})
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
