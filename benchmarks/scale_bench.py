"""Scale-frontier benchmark: APSP backend frontier, ToR-coarsened lanes,
and the persistent AOT compile cache.  Writes ``BENCH_scale.json``
(schema pinned in ``tests/test_bench_artifacts.py``).

Three sections, one uniform row schema:

* **frontier** — per ``ApspBackend``, the largest N whose APSP closure
  fits a fixed memory budget AND per-probe time budget.  Every backend
  probes the SAME degree-16 random regular graph (dense backends densify
  it; ``ell-bf`` streams the padded-ELL tables through
  ``repro.kernels.ell.ell_bf_apsp_streamed`` and never materializes a
  dense input).  Each probe is a subprocess (so ``ru_maxrss`` measures
  that probe alone and an over-budget size cannot poison the parent);
  probing stops at the first failure per backend (cost grows
  monotonically in N).  Repeated squaring materializes an O(N^3)
  broadcast, so memory caps it early; blocked Floyd-Warshall holds
  O(N^2) but pays O(N^3) work, so time caps it next; ell-bf pays
  O(N * d_max * diameter) per source block and carries the frontier past
  N=16384.  Rows record per-probe peak RSS and, for ell-bf, the
  relaxation-round count and table width.
* **coarsen** — one VL2 instance three ways: server-expanded with
  ``coarsen=False`` (models 1GbE NICs explicitly, so θ* is NIC-limited
  and lanes carry the full node count), server-expanded through the
  default engine contraction, and built directly at switch level.  The
  contracted solve must report brackets BIT-EQUAL to the switch-level
  build (coarsening is exact — same matrices, same program) while its
  lane is planned at the much smaller switch-only ``padded_n``.
* **aot** — a compile-dominated certified workload run twice in fresh
  subprocesses sharing one ``REPRO_AOT_CACHE_DIR``: the warm process
  must report ZERO new XLA compiles and well under the cold wall.

    PYTHONPATH=src python -m benchmarks.scale_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

import repro
from benchmarks.common import rows_to_csv, write_bench_json
from repro.core import traffic
from repro.core.engine import get_engine
from repro.core.vl2 import VL2Spec, vl2_topology

# the BENCH_scale.json contract (tests/test_bench_artifacts.py pins it);
# the tuple fixes the CSV column order, the frozenset is the pinned set
_ROW_ORDER = ("figure", "section", "backend", "label", "n", "padded_n",
              "ok", "wall_s", "mem_gb", "peak_rss_mb", "d_max", "rounds",
              "lb", "ub", "compiles", "hits")
SCALE_ROW_KEYS = frozenset(_ROW_ORDER)
SCALE_EXTRA_KEYS = frozenset({
    "mem_budget_gb", "time_budget_s", "frontier", "coarsen_equal",
    "warm_over_cold", "last_plan",
})

_BACKENDS = ("squaring", "blocked-fw", "ell-bf")

_PROBE_SRC = r"""
import json, resource, sys, time
from repro.core.graphs import random_regular_ell

n, backend = int(sys.argv[1]), sys.argv[2]
g = random_regular_ell(n, 16, seed=0)   # one degree-16 RRG, every backend
t0 = time.perf_counter()
if backend == "ell-bf":
    # the designed at-scale path: padded-ELL tables streamed block by
    # block, no dense [N, N] input ever materialized
    from repro.kernels.ell import ell_bf_apsp_streamed
    _, rounds = ell_bf_apsp_streamed(g.idx, g.wgt, block=min(1024, n))
    extra = {"rounds": int(rounds), "d_max": g.d_max}
else:
    import jax.numpy as jnp
    from repro.core.apsp import apsp
    apsp(jnp.asarray(g.to_dense()), backend).block_until_ready()
    extra = {"rounds": None, "d_max": None}
wall = time.perf_counter() - t0
rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
print(json.dumps({"wall_s": wall, "mem_gb": rss_kb / 1e6,
                  "peak_rss_mb": rss_kb / 1e3, **extra}))
"""

_AOT_SRC = r"""
import json, sys, time
from repro.core import aotcache, traffic
from repro.core.engine import get_engine
from repro.core.graphs import random_regular_graph

iters = int(sys.argv[2])
t0 = time.perf_counter()
topos = [random_regular_graph(n, 4, seed=s, servers=3)
         for s, n in enumerate([16, 16, 24, 32])]
dems = [traffic.make("permutation", t.servers, seed=7) for t in topos]
eng = get_engine("certified", iters=iters, aot_cache=sys.argv[1])
res = eng.solve_batch(topos, dems)
out = {"wall_s": time.perf_counter() - t0, "lb": res[0].meta["lb"]}
out.update(aotcache.stats())
print(json.dumps(out))
"""


def _child_env() -> dict:
    # repro may be a namespace package (__file__ is None): use __path__
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    return env


def _run_child(src: str, argv: list[str], timeout: float) -> dict | None:
    """Run a probe subprocess; None = failed/over-time (the probe's own
    budget verdict is the caller's job)."""
    try:
        out = subprocess.run([sys.executable, "-c", src, *argv],
                             env=_child_env(), capture_output=True,
                             text=True, timeout=timeout)
    except subprocess.TimeoutExpired:
        return None
    if out.returncode != 0:
        print(out.stderr[-2000:], file=sys.stderr)
        return None
    return json.loads(out.stdout.strip().splitlines()[-1])


def _row(**kw) -> dict:
    row = dict.fromkeys(_ROW_ORDER)
    row.update(figure="scale", **kw)
    assert set(row) == SCALE_ROW_KEYS
    return row


def _frontier_rows(grid, mem_gb, time_s) -> list[dict]:
    rows = []
    for backend in _BACKENDS:
        for n in grid:
            res = _run_child(_PROBE_SRC, [str(n), backend], timeout=time_s)
            ok = (res is not None and res["mem_gb"] <= mem_gb
                  and res["wall_s"] <= time_s)
            rows.append(_row(
                section="frontier", backend=backend, label=f"apsp-{n}",
                n=n, ok=bool(ok),
                wall_s=None if res is None else round(res["wall_s"], 3),
                mem_gb=None if res is None else round(res["mem_gb"], 3),
                peak_rss_mb=None if res is None
                else round(res["peak_rss_mb"], 1),
                d_max=None if res is None else res["d_max"],
                rounds=None if res is None else res["rounds"]))
            if not ok:          # cost is monotone in n: stop this backend
                break
    return rows


def _coarsen_rows(spec: VL2Spec, iters: int) -> list[dict]:
    direct = vl2_topology(spec)
    expanded = vl2_topology(spec, server_nodes=True)
    d_sw = traffic.make("permutation", direct.servers, seed=0)
    d_node = traffic.make("permutation", expanded.servers, seed=0)
    eng = get_engine("certified", iters=iters)
    t0 = time.time()
    uncoarse = get_engine("certified", iters=iters,
                          coarsen=False).solve_batch([expanded], [d_node])[0]
    t1 = time.time()
    coarse = eng.solve_batch([expanded], [d_node])[0]
    t2 = time.time()
    ref = eng.solve_batch([direct], [d_sw])[0]
    rows = [
        _row(section="coarsen", backend="auto", label="expanded",
             n=expanded.n, padded_n=uncoarse.meta["padded_n"],
             ok=True, wall_s=round(t1 - t0, 3),
             lb=uncoarse.meta["lb"], ub=uncoarse.meta["ub"]),
        _row(section="coarsen", backend="auto", label="coarsened",
             n=expanded.n, padded_n=coarse.meta["padded_n"],
             ok=coarse.meta["padded_n"] < expanded.n,
             wall_s=round(t2 - t1, 3),
             lb=coarse.meta["lb"], ub=coarse.meta["ub"]),
        _row(section="coarsen", backend="auto", label="switch-level",
             n=direct.n, padded_n=ref.meta["padded_n"], ok=True,
             lb=ref.meta["lb"], ub=ref.meta["ub"]),
    ]
    equal = (coarse.meta["lb"] == ref.meta["lb"]
             and coarse.meta["ub"] == ref.meta["ub"])
    if not equal:
        print("WARNING: coarsened bracket != switch-level bracket",
              file=sys.stderr)
    return rows, equal, eng.last_plan


def _aot_rows(iters: int, timeout: float) -> tuple[list[dict], float | None]:
    rows = []
    with tempfile.TemporaryDirectory(prefix="repro-aot-bench-") as d:
        cold = _run_child(_AOT_SRC, [d, str(iters)], timeout=timeout)
        warm = _run_child(_AOT_SRC, [d, str(iters)], timeout=timeout)
    ratio = None
    for label, res in (("cold", cold), ("warm", warm)):
        ok = res is not None
        if label == "warm" and ok:
            ok = res["compiles"] == 0 and res["hits"] >= 1
            if cold is not None:
                ratio = res["wall_s"] / cold["wall_s"]
                ok = ok and ratio < 0.5
        rows.append(_row(
            section="aot", backend="auto", label=label, ok=bool(ok),
            wall_s=None if res is None else round(res["wall_s"], 3),
            lb=None if res is None else res["lb"],
            compiles=None if res is None else res["compiles"],
            hits=None if res is None else res["hits"]))
    if warm is not None and warm["compiles"]:
        print("WARNING: warm AOT run recompiled", file=sys.stderr)
    return rows, ratio


def bench(scale: str = "small") -> tuple[list[dict], dict]:
    if scale == "smoke":
        grid, mem_gb, time_s, iters = [256, 512], 1.0, 60.0, 30
        spec = VL2Spec(d_a=4, d_i=4, servers_per_tor=3)
    elif scale == "paper":
        grid = [256, 512, 768, 1024, 2048, 4096, 8192, 16384]
        mem_gb, time_s, iters = 4.0, 600.0, 120
        spec = VL2Spec(d_a=8, d_i=8, servers_per_tor=10)
    else:
        grid = [256, 512, 768, 1024, 2048, 4096, 8192, 16384]
        mem_gb, time_s, iters = 1.5, 150.0, 60
        spec = VL2Spec(d_a=8, d_i=8, servers_per_tor=5)
    rows = _frontier_rows(grid, mem_gb, time_s)
    frontier = {b: max((r["n"] for r in rows if r["backend"] == b
                        and r["ok"]), default=0) for b in _BACKENDS}
    c_rows, equal, last_plan = _coarsen_rows(spec, iters)
    rows += c_rows
    a_rows, ratio = _aot_rows(iters, timeout=max(time_s, 120.0))
    rows += a_rows
    extra = {"mem_budget_gb": mem_gb, "time_budget_s": time_s,
             "frontier": frontier, "coarsen_equal": bool(equal),
             "warm_over_cold": ratio,
             "last_plan": None if last_plan is None else
             last_plan.as_dict()}
    assert set(extra) == SCALE_EXTRA_KEYS
    return rows, extra


def run(scale: str = "small") -> list[dict]:
    """``benchmarks.run`` entry point: rows only (the generic per-figure
    stats block replaces the scale extra block there)."""
    rows, _ = bench(scale)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (overrides --scale)")
    args = ap.parse_args()
    t0 = time.time()
    rows, extra = bench("smoke" if args.smoke else args.scale)
    dt = time.time() - t0
    rows_to_csv(rows)
    fr = extra["frontier"]
    head = (f"ell-bf frontier N={fr['ell-bf']} vs blocked-fw "
            f"N={fr['blocked-fw']} vs squaring N={fr['squaring']} "
            f"under {extra['mem_budget_gb']}GB")
    if extra["warm_over_cold"] is not None:
        head += f"; warm start {100 * extra['warm_over_cold']:.0f}% of cold"
    path = write_bench_json("scale", rows, headline=head, wall_s=dt,
                            extra=extra)
    print(f"{head}\nwrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
