"""Fig. 1: RRG throughput + ASPL vs the universal bounds, N fixed, degree
sweeps denser rightward.  Paper setting: N=40 switches; random-permutation
traffic with 5 and 10 servers/switch, plus all-to-all."""
from __future__ import annotations

import numpy as np

from benchmarks.common import rows_to_csv
from repro.core import as_engine, bounds, graphs, lp, traffic


def run(scale: str = "small", engine="exact") -> list[dict]:
    n = 40
    degrees = [5, 10, 15, 20, 25] if scale == "small" else \
        [5, 10, 15, 20, 25, 30, 35]
    runs = 3 if scale == "small" else 10
    eng = as_engine(engine)

    # build every (degree, traffic, run) instance, solve them in one batch
    cases = [(r, label, srv) for r in degrees
             for label, srv in (("perm-5", 5), ("perm-10", 10), ("a2a", 2))]
    topos, dems = [], []
    for r, label, srv in cases:
        for rr in range(runs):
            topo = graphs.random_regular_graph(n, r, seed=100 * r + rr,
                                               servers=srv)
            pattern = "all_to_all" if label == "a2a" else "permutation"
            topos.append(topo)
            dems.append(traffic.make(pattern, topo.servers, seed=rr))
    results = eng.solve_batch(topos, dems)

    rows = []
    for ci, (r, label, srv) in enumerate(cases):
        sl = slice(ci * runs, (ci + 1) * runs)
        ths = [res.throughput for res in results[sl]]
        ds = [lp.aspl_hops(t, d) for t, d in zip(topos[sl], dems[sl])]
        nf = traffic.num_flows(dems[sl][-1])
        ub = bounds.throughput_upper_bound(n, r, nf)
        d_star = bounds.aspl_lower_bound(n, r)
        rows.append({
            "figure": "fig1", "traffic": label, "degree": r,
            "throughput": float(np.mean(ths)),
            "throughput_std": float(np.std(ths)),
            "upper_bound": ub,
            "frac_of_bound": float(np.mean(ths)) / ub,
            "aspl": float(np.mean(ds)), "aspl_lower": d_star,
        })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
