"""Fig. 1: RRG throughput + ASPL vs the universal bounds, N fixed, degree
sweeps denser rightward.  Paper setting: N=40 switches; random-permutation
traffic with 5 and 10 servers/switch, plus all-to-all."""
from __future__ import annotations

import numpy as np

from benchmarks.common import rows_to_csv
from repro.core import bounds, graphs, lp, traffic


def run(scale: str = "small") -> list[dict]:
    n = 40
    degrees = [5, 10, 15, 20, 25] if scale == "small" else \
        [5, 10, 15, 20, 25, 30, 35]
    runs = 3 if scale == "small" else 10
    rows = []
    for r in degrees:
        for label, srv in (("perm-5", 5), ("perm-10", 10), ("a2a", 2)):
            ths, ds = [], []
            for rr in range(runs):
                cap = graphs.random_regular_graph(n, r, seed=100 * r + rr)
                servers = np.full(n, srv)
                if label == "a2a":
                    dem = traffic.all_to_all(servers)
                else:
                    dem = traffic.random_permutation(servers, seed=rr)
                ths.append(lp.max_concurrent_flow(
                    cap, dem, want_flows=False).throughput)
                ds.append(lp.aspl_hops(cap, dem))
            f = float(dem.sum()) if label == "a2a" else None
            # per-flow UB; for a2a each flow has dem 1 between server pairs
            nf = traffic.num_flows(dem)
            ub = bounds.throughput_upper_bound(n, r, nf)
            d_star = bounds.aspl_lower_bound(n, r)
            rows.append({
                "figure": "fig1", "traffic": label, "degree": r,
                "throughput": float(np.mean(ths)),
                "throughput_std": float(np.std(ths)),
                "upper_bound": ub,
                "frac_of_bound": float(np.mean(ths)) / ub,
                "aspl": float(np.mean(ds)), "aspl_lower": d_star,
            })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
