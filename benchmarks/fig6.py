"""Fig. 6: combined server-split x cross-cluster sweep — several configs tie
at the peak, and (proportional split, vanilla random) is one of them.

``het.combined_sweep`` routes the whole (split x bias) grid through one
``run_sweeps`` call, so on a batching engine the entire figure executes as
a single ``BatchPlan`` (one bucket pass, chunked/sharded over devices)."""
from __future__ import annotations

from benchmarks.common import bracket_cols, rows_to_csv
from repro.core import heterogeneous as het


def run(scale: str = "small", engine="exact") -> list[dict]:
    # 10 large (18p) / 20 small (6p), 90 servers
    spec = het.TwoClassSpec(10, 18, 20, 6, 90)
    # proportional split: large share = 90*180/300 = 54 -> ~5.4/large, 1.8/small
    splits = [(5, 2), (7, 1), (3, 3)]          # (per-large, per-small)
    splits = [s for s in splits
              if s[0] * spec.n_large + s[1] * spec.n_small
              == spec.num_servers]
    biases = [0.3, 0.7, 1.0, 1.5]
    runs = 3 if scale == "small" else 10
    out = het.combined_sweep(spec, splits, biases, runs=runs, seed0=5,
                             engine=engine)
    peak = max(p.mean for pts in out.values() for p in pts)
    rows = []
    for (pl, ps), pts in out.items():
        for p in pts:
            rows.append({"figure": "fig6", "split": f"{pl}H,{ps}L",
                         "bias": p.x, "throughput": p.mean, "std": p.std,
                         "frac_of_peak": p.mean / peak,
                         **bracket_cols(p)})
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
