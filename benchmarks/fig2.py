"""Fig. 2: RRG throughput + ASPL vs bounds, degree fixed (10), network size
sweeps sparser rightward.  Shows the optimality-gap peak-then-shrink shape."""
from __future__ import annotations

import numpy as np

from benchmarks.common import rows_to_csv
from repro.core import bounds, graphs, lp, traffic


def run(scale: str = "small") -> list[dict]:
    r = 10
    sizes = [15, 20, 30, 40, 60] if scale == "small" else \
        [15, 20, 30, 40, 60, 80, 120, 160]
    runs = 3 if scale == "small" else 10
    rows = []
    for n in sizes:
        ths, ds = [], []
        for rr in range(runs):
            cap = graphs.random_regular_graph(n, r, seed=10_000 + n + rr)
            servers = np.full(n, 5)
            dem = traffic.random_permutation(servers, seed=rr)
            ths.append(lp.max_concurrent_flow(
                cap, dem, want_flows=False).throughput)
            ds.append(lp.aspl_hops(cap, dem))
        nf = traffic.num_flows(dem)
        ub = bounds.throughput_upper_bound(n, r, nf)
        rows.append({
            "figure": "fig2", "size": n, "degree": r,
            "throughput": float(np.mean(ths)),
            "upper_bound": ub,
            "frac_of_bound": float(np.mean(ths)) / ub,
            "aspl": float(np.mean(ds)),
            "aspl_lower": bounds.aspl_lower_bound(n, r),
        })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
