"""Fig. 2: RRG throughput + ASPL vs bounds, degree fixed (10), network size
sweeps sparser rightward.  Shows the optimality-gap peak-then-shrink shape."""
from __future__ import annotations

import numpy as np

from benchmarks.common import rows_to_csv
from repro.core import as_engine, bounds, graphs, lp, traffic


def run(scale: str = "small", engine="exact") -> list[dict]:
    r = 10
    sizes = [15, 20, 30, 40, 60] if scale == "small" else \
        [15, 20, 30, 40, 60, 80, 120, 160]
    runs = 3 if scale == "small" else 10
    eng = as_engine(engine)

    topos, dems = [], []
    for n in sizes:
        for rr in range(runs):
            topo = graphs.random_regular_graph(n, r, seed=10_000 + n + rr,
                                               servers=5)
            topos.append(topo)
            dems.append(traffic.make("permutation", topo.servers, seed=rr))
    results = eng.solve_batch(topos, dems)

    rows = []
    for si, n in enumerate(sizes):
        sl = slice(si * runs, (si + 1) * runs)
        ths = [res.throughput for res in results[sl]]
        ds = [lp.aspl_hops(t, d) for t, d in zip(topos[sl], dems[sl])]
        nf = traffic.num_flows(dems[sl][-1])
        ub = bounds.throughput_upper_bound(n, r, nf)
        rows.append({
            "figure": "fig2", "size": n, "degree": r,
            "throughput": float(np.mean(ths)),
            "upper_bound": ub,
            "frac_of_bound": float(np.mean(ths)) / ub,
            "aspl": float(np.mean(ds)),
            "aspl_lower": bounds.aspl_lower_bound(n, r),
        })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
