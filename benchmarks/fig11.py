"""Fig. 11: improving VL2 by rewiring the same equipment — ToRs supported at
full throughput, for (a) random-permutation and (c) 100% stride traffic.

Two rewired paths per spec: the paper's hand-coded proportional rewiring
(``rewired_vl2_topology``) and the fleet optimizer's wiring
(``designed_vl2_topology``, permutation traffic only — the optimizer
searches from the recipe with a smoke budget, so ``designed_tors >=
rewired_tors`` whenever the search finds any slack)."""
from __future__ import annotations

import functools

from benchmarks.common import rows_to_csv
from repro.core import traffic, vl2
from repro.core.engine import DualEngine


def run(scale: str = "small", engine="exact") -> list[dict]:
    sizes = [(4, 4), (6, 6), (8, 8)] if scale == "small" else \
        [(4, 4), (6, 6), (8, 8), (10, 10)]
    runs = 2 if scale == "small" else 5
    # smoke-budget designer: cheap dual ranking, small fleets — each probe
    # of the designed binary search runs rounds+2 BatchPlan executes.
    # runs=3 matters: with fewer in-search traffic samples the search can
    # overfit its samples and lose ToRs on the figure's held-out criterion
    design_build = functools.partial(
        vl2.designed_vl2_topology, rounds=2, fleet=6, runs=3,
        engine=DualEngine(iters=200, tol=1e-3))
    rows = []
    for d_a, d_i in sizes:
        spec = vl2.VL2Spec(d_a=d_a, d_i=d_i, servers_per_tor=20)
        base = spec.n_tor_full
        for tname, tfn in (
            ("permutation", None),
            ("stride100", lambda servers, seed: traffic.stride(
                servers, 1.0, seed)),
        ):
            best = vl2.max_tors_at_full_throughput(
                spec, vl2.rewired_vl2_topology, lo=base,
                hi=base + max(2, base // 2), runs=runs, seed0=2,
                engine=engine, traffic_fn=tfn)
            designed = None
            if tname == "permutation":
                # start the search at the hand-rewired optimum: the recipe
                # is the designer's candidate 0, so it can only gain
                designed = vl2.max_tors_at_full_throughput(
                    spec, design_build, lo=best,
                    hi=best + max(2, base // 2), runs=runs, seed0=2,
                    engine=engine, traffic_fn=tfn)
            rows.append({
                "figure": "fig11", "d_a": d_a, "d_i": d_i,
                "traffic": tname,
                "vl2_tors": base, "rewired_tors": best,
                "gain_pct": 100.0 * (best - base) / base,
                "designed_tors": designed,
                "designed_gain_pct":
                    None if designed is None
                    else 100.0 * (designed - base) / base,
                "vl2_servers": base * spec.servers_per_tor,
                "rewired_servers": best * spec.servers_per_tor,
            })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
