"""Fig. 11: improving VL2 by rewiring the same equipment — ToRs supported at
full throughput, for (a) random-permutation and (c) 100% stride traffic."""
from __future__ import annotations

from benchmarks.common import rows_to_csv
from repro.core import traffic, vl2


def run(scale: str = "small", engine="exact") -> list[dict]:
    sizes = [(4, 4), (6, 6), (8, 8)] if scale == "small" else \
        [(4, 4), (6, 6), (8, 8), (10, 10)]
    runs = 2 if scale == "small" else 5
    rows = []
    for d_a, d_i in sizes:
        spec = vl2.VL2Spec(d_a=d_a, d_i=d_i, servers_per_tor=20)
        base = spec.n_tor_full
        for tname, tfn in (
            ("permutation", None),
            ("stride100", lambda servers, seed: traffic.stride(
                servers, 1.0, seed)),
        ):
            best = vl2.max_tors_at_full_throughput(
                spec, vl2.rewired_vl2_topology, lo=base,
                hi=base + max(2, base // 2), runs=runs, seed0=2,
                engine=engine, traffic_fn=tfn)
            rows.append({
                "figure": "fig11", "d_a": d_a, "d_i": d_i,
                "traffic": tname,
                "vl2_tors": base, "rewired_tors": best,
                "gain_pct": 100.0 * (best - base) / base,
                "vl2_servers": base * spec.servers_per_tor,
                "rewired_servers": best * spec.servers_per_tor,
            })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
