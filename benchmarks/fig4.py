"""Fig. 4: power-law port counts, servers attached prop. to k^beta;
beta=1 is among the optimal settings."""
from __future__ import annotations

from benchmarks.common import rows_to_csv
from repro.core import heterogeneous as het


def run(scale: str = "small", engine="exact") -> list[dict]:
    n, servers = (24, 60) if scale == "small" else (60, 200)
    runs = 3 if scale == "small" else 10
    betas = [0.0, 0.5, 0.8, 1.0, 1.2, 1.4, 2.0]
    pts = het.power_law_beta_sweep(n=n, k_min=4, k_max=24, alpha=2.0,
                                   num_servers=servers, betas=betas,
                                   runs=runs, seed0=11, engine=engine)
    best = max(pts, key=lambda p: p.mean)
    return [{"figure": "fig4", "beta": p.x, "throughput": p.mean,
             "std": p.std, "best_beta": best.x} for p in pts]


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
