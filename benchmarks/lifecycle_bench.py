"""Lifecycle benchmark: certified degradation curves + budgeted growth.

Drives the two halves of ``repro.lifecycle`` at tracked sizes and writes
``BENCH_lifecycle.json`` (schema pinned in
``tests/test_bench_artifacts.py``):

* **Degradation** — three topology families (RRG, biased two-cluster,
  rewired VL2) × three failure kinds (independent links, switch deaths,
  correlated shared-risk groups) × failure fractions × trials, all
  through the planner: ONE ``BatchPlan.execute`` per failure kind, later
  kinds ``refill``-ing the first kind's plan, the whole surface held to a
  single-digit compile-key set (asserted ≤ 4 here).  Rows are the
  certified curve points: lb quantile band, mean ub, worst bracket gap,
  and ``reachable_mean`` — the demand share still routable.
* **Expansion** — a ≥3-step VL2 fabric growth under a recabling budget;
  the per-step certified lb trajectory is asserted monotone
  non-decreasing and every step's recabled-link count within budget.

Two producers write this filename: THIS entry point (what CI runs)
attaches the lifecycle extra block (``LIFECYCLE_EXTRA_KEYS``), while
``benchmarks.run --only lifecycle`` wraps the same rows in the generic
per-figure stats block.  The rows are identical either way.

    PYTHONPATH=src python -m benchmarks.lifecycle_bench [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import rows_to_csv, write_bench_json
from repro.core import vl2
from repro.core.engine import CertifiedEngine
from repro.core.graphs import (biased_two_cluster_graph,
                               random_regular_graph)
from repro.lifecycle import degradation_surface, plan_expansion

# the BENCH_lifecycle.json contract (tests/test_bench_artifacts.py pins
# it): per-curve-point row keys, and the artifact-level extra block
LIFECYCLE_ROW_KEYS = frozenset({
    "figure", "family", "kind", "fraction", "trials", "lb_q10", "lb_med",
    "lb_q90", "ub_mean", "gap_max", "reachable_mean", "dead_trials",
})
LIFECYCLE_EXTRA_KEYS = frozenset({
    "compile_keys", "executes", "refills", "last_plan", "expansion",
})
# per-step keys inside extra["expansion"]["steps"]
EXPANSION_STEP_KEYS = frozenset({
    "step", "nodes", "new_switches", "new_ports", "spare_ports",
    "recabled", "lb", "ub", "lb_source", "chose",
})


def _families(smoke: bool, paper: bool, seed: int = 0):
    """Three families sized so the whole degraded fleet lands in at most
    two plan buckets (RRG and two-cluster share one pow2 bucket, the
    small VL2 the other) — that is what keeps the surface <= 4 keys."""
    if paper:
        n, r, sp = 40, 6, 3
        spec = vl2.VL2Spec(d_a=6, d_i=4, servers_per_tor=4)
        n_tor = 8
    else:
        n, r, sp = 24, 5, 3
        spec = vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=4)
        n_tor = 4
    half = n // 2
    return {
        "rrg": random_regular_graph(n, r, seed=seed, servers=sp),
        "two_cluster": biased_two_cluster_graph(
            [r] * half, [r] * half, cross_bias=0.5, seed=seed, servers=sp),
        "vl2": vl2.rewired_vl2_topology(spec, n_tor, seed=seed),
    }


def _vl2_forbidden(topo):
    tor = topo.labels == 0
    return tor[:, None] & tor[None, :]


def _degradation_rows(scale: str, engine) -> tuple[list[dict], dict]:
    smoke = scale == "smoke"
    fams = _families(smoke, scale == "paper")
    fractions = (0.1, 0.25, 0.5) if smoke else \
        (0.05, 0.1, 0.2, 0.3, 0.45)
    trials = 4 if smoke else (30 if scale == "paper" else 20)
    res = degradation_surface(fams, fractions=fractions, trials=trials,
                              engine=engine, seed=0)
    rows = [{
        "figure": "lifecycle", "family": p.family, "kind": p.kind,
        "fraction": p.fraction, "trials": p.trials, "lb_q10": p.lb_q10,
        "lb_med": p.lb_med, "lb_q90": p.lb_q90, "ub_mean": p.ub_mean,
        "gap_max": p.gap_max, "reachable_mean": p.reachable_mean,
        "dead_trials": p.dead_trials,
    } for p in res.points]
    s = res.stats
    # the whole surface through the planner: one execute per failure
    # kind, refills keeping the compile-key set single-digit
    assert s["executes"] == len(s["kinds"]), s
    assert s["refills"] == len(s["kinds"]) - 1, s
    assert len(s["compile_keys"]) <= 4, \
        f"degradation surface leaked compile keys: {s['compile_keys']}"
    assert all(0.0 <= r["reachable_mean"] <= 1.0 for r in rows)
    assert all(r["lb_q10"] <= r["lb_med"] <= r["lb_q90"] + 1e-12
               for r in rows)
    # per-trial lb <= ub is the certificate; aggregates (median lb vs
    # mean ub) are NOT comparable across heterogeneous failure draws
    assert all(r["gap_max"] >= -1e-9 for r in rows)
    extra = {"compile_keys": [list(k) for k in s["compile_keys"]],
             "executes": s["executes"], "refills": s["refills"],
             "last_plan": s["last_plan"]}
    return rows, extra


def _expansion_block(scale: str, engine) -> dict:
    smoke = scale == "smoke"
    spec = vl2.VL2Spec(d_a=4, d_i=2, servers_per_tor=4)
    start = vl2.rewired_vl2_topology(spec, n_tor=4, seed=0)
    # two new cores per step so the budgeted swap search has room (added
    # links then span two distinct new endpoints — see ExpansionSpace)
    growth = [[4, 4]] * 3
    budget = 3
    res = plan_expansion(
        start, growth, max_recabled_links=budget, engine=engine,
        new_labels=[2], forbidden_fn=_vl2_forbidden,
        link_unit=vl2.FABRIC,
        rounds=1 if smoke else 2, fleet=4 if smoke else 6,
        elite=2, runs=2, seed=0)
    lbs = [st.lb for st in res.steps]
    # the whole point: certified lb monotone non-decreasing in equipment,
    # and every step's recabling within budget
    assert all(b >= a - 1e-9 for a, b in zip(lbs, lbs[1:])), \
        f"expansion lb trajectory not monotone: {lbs}"
    assert all(st.recabled <= budget for st in res.steps), \
        [st.recabled for st in res.steps]
    steps = [{
        "step": i, "nodes": st.topo.n, "new_switches": st.new_switches,
        "new_ports": st.new_ports, "spare_ports": st.spare_ports,
        "recabled": st.recabled, "lb": st.lb, "ub": st.ub,
        "lb_source": st.lb_source, "chose": st.chose,
    } for i, st in enumerate(res.steps)]
    assert all(set(st) == EXPANSION_STEP_KEYS for st in steps)
    return {"steps": steps, "max_recabled_links": budget,
            "growth_gain_pct": 100.0 * (lbs[-1] / lbs[0] - 1)
            if lbs[0] > 0 else 0.0,
            "executes": res.stats["executes"],
            "compile_keys": [list(k) for k in res.stats["compile_keys"]]}


def bench(scale: str = "small", engine=None) -> tuple[list[dict], dict]:
    """(rows, artifact-extra) of the lifecycle benchmark.  ``engine`` is
    accepted for ``benchmarks.run`` uniformity; anything that is not a
    primal-certifying planning engine falls back to the default
    ``CertifiedEngine`` (the curves ARE certified brackets)."""
    smoke = scale == "smoke"
    if engine is None or getattr(engine, "solver", None) != "primal":
        engine = CertifiedEngine(iters=60 if smoke else 300, tol=1e-3)
    rows, extra = _degradation_rows(scale, engine)
    extra["expansion"] = _expansion_block(scale, engine)
    assert all(set(r) == LIFECYCLE_ROW_KEYS for r in rows)
    assert set(extra) == LIFECYCLE_EXTRA_KEYS
    return rows, extra


def run(scale: str = "small", engine=None) -> list[dict]:
    """``benchmarks.run`` entry point (rows only)."""
    return bench(scale, engine)[0]


def _headline(rows: list[dict], extra: dict) -> str:
    links10 = [r for r in rows
               if r["kind"] == "links" and abs(r["fraction"] - 0.1) < 0.06]
    intact = {r["family"]: r for r in rows}   # overwritten; lowest frac kept
    for r in sorted(rows, key=lambda r: -r["fraction"]):
        if r["kind"] == "links":
            intact[r["family"]] = r
    keep = min((r["lb_med"] / max(intact[r["family"]]["lb_med"], 1e-30)
                for r in links10), default=float("nan"))
    g = extra["expansion"]["growth_gain_pct"]
    return (f"10% link cuts keep >= {100 * keep:.0f}% certified lb; "
            f"3-step growth +{g:.1f}% lb within budget")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI budget: 3 fractions, 4 trials, 60 iters")
    args = ap.parse_args()
    t0 = time.time()
    rows, extra = bench("smoke" if args.smoke else args.scale)
    rows_to_csv(rows)
    path = write_bench_json("lifecycle", rows, wall_s=time.time() - t0,
                            headline=_headline(rows, extra), extra=extra)
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
