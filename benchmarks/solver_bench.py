"""Engine benchmark: HiGHS exact LP vs the JAX dual MCF solver (the CPLEX
replacement) — accuracy and wall time, including the vmapped batch mode that
turns the paper's '20 runs per point' into one device program."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import rows_to_csv
from repro.core import graphs, lp, mcf, traffic


def run(scale: str = "small") -> list[dict]:
    sizes = [(20, 6), (40, 10)] if scale == "small" else \
        [(20, 6), (40, 10), (80, 10), (120, 12)]
    rows = []
    for n, r in sizes:
        cap = graphs.random_regular_graph(n, r, seed=1)
        dem = traffic.random_permutation(np.full(n, 5), seed=2)
        t0 = time.time()
        exact = lp.max_concurrent_flow(cap, dem, want_flows=False).throughput
        t_lp = time.time() - t0
        t0 = time.time()
        dual = mcf.solve_dual(cap, dem, iters=600)
        t_dual = time.time() - t0
        # batched: 8 instances in one vmapped solve
        caps = np.stack([graphs.random_regular_graph(n, r, seed=s)
                         for s in range(8)])
        dems = np.stack([traffic.random_permutation(np.full(n, 5), seed=s)
                         for s in range(8)])
        t0 = time.time()
        mcf.solve_dual_batch(caps, dems, iters=600)
        t_batch = time.time() - t0
        rows.append({
            "figure": "solver", "n": n, "deg": r,
            "exact": exact, "dual_ub": dual.throughput_ub,
            "gap_pct": 100 * (dual.throughput_ub / exact - 1),
            "lp_s": t_lp, "dual_s": t_dual,
            "batch8_s": t_batch, "batch_speedup": 8 * t_dual / t_batch,
        })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
