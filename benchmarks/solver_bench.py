"""Engine benchmark: the unified ThroughputEngine backends head to head —
exact HiGHS LP vs the JAX dual solver (the CPLEX replacement) — accuracy and
wall time, including the batched ``solve_batch`` mode that turns the paper's
'20 runs per point' into one vmapped device program."""
from __future__ import annotations

import time

from benchmarks.common import rows_to_csv
from repro.core import get_engine, graphs, traffic


def run(scale: str = "small") -> list[dict]:
    sizes = [(20, 6), (40, 10)] if scale == "small" else \
        [(20, 6), (40, 10), (80, 10), (120, 12)]
    exact_eng = get_engine("exact")
    dual_eng = get_engine("dual", iters=600)
    rows = []
    for n, r in sizes:
        topo = graphs.random_regular_graph(n, r, seed=1, servers=5)
        dem = traffic.make("permutation", topo.servers, seed=2)
        t0 = time.time()
        exact = exact_eng.solve(topo, dem).throughput
        t_lp = time.time() - t0
        t0 = time.time()
        dual = dual_eng.solve(topo, dem)
        t_dual = time.time() - t0
        # batched: 8 instances through one solve_batch (one vmapped program)
        topos = [graphs.random_regular_graph(n, r, seed=s, servers=5)
                 for s in range(8)]
        dems = [traffic.make("permutation", t.servers, seed=s)
                for s, t in enumerate(topos)]
        t0 = time.time()
        dual_eng.solve_batch(topos, dems)
        t_batch = time.time() - t0
        rows.append({
            "figure": "solver", "n": n, "deg": r,
            "exact": exact, "dual_ub": dual.throughput,
            "gap_pct": 100 * (dual.throughput / exact - 1),
            "lp_s": t_lp, "dual_s": t_dual,
            "batch8_s": t_batch, "batch_speedup": 8 * t_dual / t_batch,
        })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
