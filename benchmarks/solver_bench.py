"""Engine benchmark: the unified ThroughputEngine backends head to head —
exact HiGHS LP vs the JAX dual solver (the CPLEX replacement) vs the
Frank–Wolfe primal solver (certified lower bounds) — accuracy and wall
time, including the batched ``solve_batch`` mode that turns the paper's
'20 runs per point' into one vmapped device program.  Every row reports
the certified bracket the primal+dual pair produces around the exact LP
value.

``--mixed`` benchmarks the ``BatchPlan`` execution core on a heterogeneous
sweep (the Figs. 3-7 shape: many topology sizes, many runs per size) in
four plans: the per-exact-size grouping baseline (one XLA compile per
distinct node count, fixed iterations), the 1-device bucketed plan (one
compile per bucket, early stopping), — when several local devices are
visible, e.g. under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
— the sharded plan (chunked under a lane budget, batch axis sharded over
all devices, async dispatch), and the primal plan (the Frank–Wolfe lower
bound riding the same bucketed/sharded path; its ``compile_keys`` column
shows primal lanes reuse the plan shapes — no per-instance recompiles).
``--smoke`` runs one tiny sweep per registered engine (CI regression
canary).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import rows_to_csv, write_bench_json
from repro.core import get_engine, graphs, mcf, traffic
from repro.core import plan as plan_mod
from repro.core.engine import DualEngine, PrimalEngine


def run(scale: str = "small") -> list[dict]:
    sizes = [(20, 6), (40, 10)] if scale == "small" else \
        [(20, 6), (40, 10), (80, 10), (120, 12)]
    exact_eng = get_engine("exact")
    dual_eng = get_engine("dual", iters=600)
    primal_eng = get_engine("primal", iters=600)
    rows = []
    for n, r in sizes:
        topo = graphs.random_regular_graph(n, r, seed=1, servers=5)
        dem = traffic.make("permutation", topo.servers, seed=2)
        t0 = time.time()
        exact = exact_eng.solve(topo, dem).throughput
        t_lp = time.time() - t0
        t0 = time.time()
        dual = dual_eng.solve(topo, dem)
        t_dual = time.time() - t0
        t0 = time.time()
        prim = primal_eng.solve(topo, dem)
        t_primal = time.time() - t0
        # batched: 8 instances through one solve_batch (one vmapped program)
        topos = [graphs.random_regular_graph(n, r, seed=s, servers=5)
                 for s in range(8)]
        dems = [traffic.make("permutation", t.servers, seed=s)
                for s, t in enumerate(topos)]
        t0 = time.time()
        dual_eng.solve_batch(topos, dems)
        t_batch = time.time() - t0
        rows.append({
            "figure": "solver", "n": n, "deg": r,
            "exact": exact, "dual_ub": dual.throughput,
            "primal_lb": prim.throughput,
            "gap_pct": 100 * (dual.throughput / exact - 1),
            "lb_gap_pct": 100 * (1 - prim.throughput / exact),
            "bracket_gap_pct":
                100 * (1 - prim.throughput / dual.throughput),
            "lp_s": t_lp, "dual_s": t_dual, "primal_s": t_primal,
            "batch8_s": t_batch, "batch_speedup": 8 * t_dual / t_batch,
        })
    return rows


def _mixed_instances(sizes, runs, deg=10):
    topos, dems = [], []
    for n in sizes:
        for s in range(runs):
            t = graphs.random_regular_graph(n, deg, seed=1000 * n + s,
                                            servers=5)
            topos.append(t)
            dems.append(traffic.make("permutation", t.servers,
                                     seed=1000 * n + s + 1))
    return topos, dems


def run_mixed(scale: str = "small", bucket: str | int | None = 8,
              tol: float = 1e-4, iters: int | None = None,
              devices: int | None = None,
              max_lanes: int | None = None) -> list[dict]:
    """Mixed-size sweep through three ``BatchPlan``s: the pre-bucketing
    baseline (group by exact size, fixed iteration count, one device), the
    1-device bucketed plan (early stopping, one compile per bucket), and —
    with >1 visible device — the sharded plan (buckets chunked under
    ``max_lanes``, each chunk's batch axis sharded over ``devices``, all
    chunks dispatched asynchronously).  Every plan is spot-checked for
    bound quality against per-instance ``solve_dual`` at the full
    iteration cap on a subsample of instances (not part of the timing).

    Bucket granularity trades compile count against padding flops: on CPU
    (where the padded (min,+) work is real) a fine granularity like 16 wins;
    on TPU the Pallas kernel pads every instance to 128-multiples internally,
    so coarse ``"pow2"``/``"mult128"`` buckets cost nothing extra and
    maximise compile reuse.  The chunk lane budget adds a second lever: small
    chunks retire as soon as THEIR slowest lane converges instead of waiting
    on the whole bucket's slowest lane, and overlap across devices."""
    import jax

    if scale == "small":
        sizes, runs, iters = list(range(12, 41, 2)), 5, iters or 800
    else:
        sizes, runs, iters = list(range(40, 65, 2)), 20, iters or 800
    topos, dems = _mixed_instances(sizes, runs, deg=8)
    # per-instance references at the full iteration cap, on a subsample
    # (full references would dwarf the benchmark itself)
    step = max(1, len(topos) // 12)
    ref_idx = list(range(0, len(topos), step))
    refs = {i: mcf.solve_dual(topos[i], dems[i], iters=iters).throughput_ub
            for i in ref_idx}
    ndev = devices or len(jax.local_devices())
    modes = [
        ("per-size", DualEngine, dict(bucket=None, tol=0.0, devices=1)),
        ("bucketed-1dev", DualEngine, dict(bucket=bucket, tol=tol,
                                           devices=1)),
    ]
    if ndev > 1:
        # one lane per device: the smallest chunk shape — cheapest compiles,
        # earliest per-chunk retirement, still a full-width device launch
        modes.append(("sharded", DualEngine,
                      dict(bucket=bucket, tol=tol, devices=ndev,
                           max_lanes=max_lanes or ndev)))
        modes.append(("primal-sharded", PrimalEngine,
                      dict(bucket=bucket, tol=tol, devices=ndev,
                           max_lanes=max_lanes or ndev)))
    else:
        # primal lower bounds through the same bucketed plan shapes as the
        # dual — its compile_keys/compiles columns pin "no per-instance
        # recompiles" for the FW path too
        modes.append(("primal-1dev", PrimalEngine,
                      dict(bucket=bucket, tol=tol, devices=1)))
    rows = []
    for label, cls, kw in modes:
        eng = cls(iters=iters, **kw)
        cache_key = f"{eng.solver}.solve_batch"
        c0 = plan_mod.compile_cache_sizes()[cache_key]
        t0 = time.time()
        out = eng.solve_batch(topos, dems)
        wall = time.time() - t0
        c1 = plan_mod.compile_cache_sizes()[cache_key]
        compiles = c1 - c0 if c0 is not None and c1 is not None else None
        if eng.solver == "primal":
            # max_rel_dev = worst certified bracket gap vs the dual refs
            assert all(out[i].throughput <= refs[i] * (1 + 1e-4)
                       for i in ref_idx), "primal lb must stay below dual ub"
            dev = max(1 - out[i].throughput / refs[i] for i in ref_idx)
        else:
            dev = max(abs(out[i].throughput / refs[i] - 1) for i in ref_idx)
        plan = eng.last_plan
        mean_iters = float(np.mean([r.meta["iterations"] for r in out]))
        rows.append({
            "figure": "solver_mixed", "mode": label, "instances": len(topos),
            "distinct_sizes": len(sizes), "buckets": plan.buckets,
            "chunks": plan.chunks, "devices": plan.devices,
            "compile_keys": len(plan.compile_keys), "compiles": compiles,
            "wall_s": wall, "mean_iters": mean_iters, "max_rel_dev": dev,
        })
    base, plan_1dev = rows[0], rows[1]
    for r in rows:
        r["speedup_vs_per_size"] = base["wall_s"] / r["wall_s"]
        # the headline number: every plan vs the 1-device bucketed plan
        # (for the sharded row this is the multi-device speedup)
        r["speedup_vs_1dev_plan"] = plan_1dev["wall_s"] / r["wall_s"]
    return rows


def run_smoke() -> list[dict]:
    """One tiny mixed-size sweep per engine — fails fast on engine-registry
    or batching regressions (used by CI).  Also crosses the Pallas (min,+)
    kernel itself once in interpret mode: the sweep instances below are
    small enough to take the reference fallback inside
    ``ops.minplus_matmul``, so without this a kernel regression would slip
    past the smoke."""
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    w = jnp.where(jnp.eye(128, dtype=bool), 0.0, 1.0)
    d2 = np.asarray(kops.minplus_matmul(w, w, 128, True))
    assert np.allclose(np.diag(d2), 0.0) and np.allclose(
        d2[~np.eye(128, dtype=bool)], 1.0), "pallas minplus kernel broken"

    topos, dems = _mixed_instances([12, 16], runs=5, deg=4)
    engines = [
        get_engine("exact"),
        get_engine("dual", iters=60, tol=1e-3),
        get_engine("dual-pallas", iters=60, tol=1e-3, interpret=True),
        get_engine("primal", iters=60, tol=1e-3),
        get_engine("certified", iters=60, tol=1e-3),
    ]
    import jax
    multi_dev = len(jax.local_devices()) > 1
    if multi_dev:
        # exercise the sharded MULTI-chunk BatchPlan path too (CI runs this
        # under XLA_FLAGS=--xla_force_host_platform_device_count=8; the 10
        # instances above split into >= 2 chunks at one lane per device)
        engines.append(get_engine("dual", iters=60, tol=1e-3,
                                  max_lanes=2))
    rows = []
    for eng in engines:
        t0 = time.time()
        out = eng.solve_batch(topos, dems)
        assert len(out) == len(topos)
        assert all(r.throughput > 0 and r.engine == eng.name for r in out)
        if eng.name == "certified":
            assert all(0 <= r.meta["lb"] <= r.meta["ub"] and
                       np.isfinite(r.meta["gap"]) for r in out), \
                "certified smoke must produce finite brackets"
        plan = getattr(eng, "last_plan", None)
        rows.append({"figure": "solver_smoke", "engine": eng.name,
                     "instances": len(out), "wall_s": time.time() - t0,
                     "devices": plan.devices if plan else 1,
                     "chunks": plan.chunks if plan else 0,
                     "mean_throughput":
                         float(np.mean([r.throughput for r in out]))})
    if multi_dev and len(jax.local_devices()) < len(topos):
        # with fewer devices than instances the lane budget must split the
        # bucket; with >= len(topos) devices one chunk holds everything
        assert rows[-1]["chunks"] > 1, \
            "sharded smoke engine must dispatch multiple chunks"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", default="small", choices=["small", "paper"])
    ap.add_argument("--bucket", default="8",
                    help="bucket mode for --mixed: pow2|mult128|<int>|none "
                         "(fine int granularity suits CPU; pow2/mult128 "
                         "suit accelerators)")
    ap.add_argument("--tol", type=float, default=1e-4,
                    help="early-stop relative-improvement tolerance for the "
                         "bucketed --mixed mode (0 = off)")
    ap.add_argument("--devices", type=int, default=None,
                    help="devices for the sharded --mixed plan "
                         "(default: all local devices)")
    ap.add_argument("--max-lanes", type=int, default=None,
                    help="chunk lane budget for the sharded --mixed plan "
                         "(default: one lane per device)")
    ap.add_argument("--mixed", action="store_true",
                    help="run the mixed-size BatchPlan benchmark "
                         "(per-size vs bucketed vs sharded)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the tiny per-engine CI smoke sweep")
    args = ap.parse_args()
    bucket = args.bucket if not args.bucket.isdigit() else int(args.bucket)
    t0 = time.time()
    if args.smoke:
        name, rows = "solver_smoke", run_smoke()
    elif args.mixed:
        name, rows = "solver_mixed", run_mixed(args.scale, bucket, args.tol,
                                               devices=args.devices,
                                               max_lanes=args.max_lanes)
    else:
        name, rows = "solver", run(args.scale)
    rows_to_csv(rows)
    path = write_bench_json(name, rows, wall_s=time.time() - t0,
                            extra={"compiles":
                                   plan_mod.compile_cache_sizes()})
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
