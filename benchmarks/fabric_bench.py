"""Beyond-paper: the paper's design rules applied to the training fabric —
achievable cross-pod collective bandwidth of a paper-rule pod interconnect
vs ToR-style packing, for the collective patterns the trainer issues."""
from __future__ import annotations

from benchmarks.common import rows_to_csv
from repro.core import fabric


def run(scale: str = "small", engine="exact") -> list[dict]:
    runs = 2 if scale == "small" else 5
    rows = []
    inventories = {
        "4x24+8x8": [24] * 4 + [8] * 8,
        "2x32+12x8": [32] * 2 + [8] * 12,
    }
    for name, ports in inventories.items():
        for pattern in ("ring", "alltoall", "allgather"):
            cmp = fabric.compare_with_traditional(
                ports, num_pods=12, nics_per_pod=1, link_gbps=25.0,
                pattern=pattern, runs=runs, seed0=23, engine=engine)
            rows.append({
                "figure": "fabric", "inventory": name, "pattern": pattern,
                "paper_gbps": cmp["paper"],
                "traditional_gbps": cmp["traditional"],
                "gain_x": cmp["paper"] / cmp["traditional"],
            })
    return rows


def main() -> None:
    rows_to_csv(run())


if __name__ == "__main__":
    main()
