"""Shared helpers for the per-figure benchmark harnesses.

Every figure module exposes ``run(scale="small") -> list[dict]`` and a
``main()`` that prints a CSV.  ``scale`` controls instance sizes so the full
suite stays tractable on one CPU ("small": minutes) while preserving each
figure's qualitative conclusion; "paper" sizes match the paper's smallest
published configuration.
"""
from __future__ import annotations

import csv
import json
import os
import sys
import time


def _json_default(v):
    """Coerce numpy scalars (and anything else stray) into JSON."""
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


def write_bench_json(name: str, rows: list[dict], *, headline: str = "",
                     wall_s: float | None = None, extra: dict | None = None,
                     out_dir: str | None = None) -> str:
    """Write the machine-readable twin of a benchmark's stdout CSV:
    ``<out_dir>/BENCH_<name>.json`` with the rows, the derived headline,
    wall time, and any ``extra`` stats (plan/compile counters), so the perf
    trajectory is tracked across PRs.  ``out_dir`` defaults to
    ``$BENCH_OUT_DIR`` or ``bench_artifacts``.  Returns the path written."""
    out_dir = out_dir or os.environ.get("BENCH_OUT_DIR", "bench_artifacts")
    os.makedirs(out_dir, exist_ok=True)
    payload = {"name": name, "generated_unix": time.time(),
               "wall_s": wall_s, "headline": headline, "rows": rows}
    if extra:
        payload.update(extra)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=_json_default)
    return path


def rows_to_csv(rows: list[dict], file=None) -> str:
    if not rows:
        return ""
    file = file or sys.stdout
    cols = list(rows[0])
    w = csv.DictWriter(file, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow({k: (f"{v:.4f}" if isinstance(v, float) else v)
                    for k, v in r.items()})
    return ""


def bench_extra(*, scale: str, engine: str, compiles: dict,
                last_plan) -> dict:
    """The per-figure stats block ``benchmarks.run`` attaches to every
    ``BENCH_<name>.json``: scale/engine, per-solver XLA compile deltas,
    the figure's final plan stats (``PlanStats.as_dict()`` or None), and
    ``max_gap`` — the figure's worst certified bracket gap, filled in by
    the caller from the rows.  ``tests/test_bench_artifacts.py`` pins
    these keys; artifact consumers rely on them."""
    return {"scale": scale, "engine": engine, "compiles": compiles,
            "last_plan": last_plan, "max_gap": None}


def max_bracket_gap(rows: list[dict]):
    """Worst per-row certified bracket ``gap`` across a figure's rows
    (None when the engine produced no brackets)."""
    gaps = [r["gap"] for r in rows if isinstance(r, dict) and "gap" in r]
    return max(gaps) if gaps else None


def bracket_cols(point) -> dict:
    """Bracket columns for one ``SweepPoint`` row: ``{"gap": worst
    relative (ub-lb)/ub across the point's runs}`` when the engine
    produced certified brackets, ``{}`` otherwise — so CSV schemas stay
    uniform within a run."""
    return {} if point.gap_max is None else {"gap": point.gap_max}


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0
