"""Shared helpers for the per-figure benchmark harnesses.

Every figure module exposes ``run(scale="small") -> list[dict]`` and a
``main()`` that prints a CSV.  ``scale`` controls instance sizes so the full
suite stays tractable on one CPU ("small": minutes) while preserving each
figure's qualitative conclusion; "paper" sizes match the paper's smallest
published configuration.
"""
from __future__ import annotations

import csv
import sys
import time


def rows_to_csv(rows: list[dict], file=None) -> str:
    if not rows:
        return ""
    file = file or sys.stdout
    cols = list(rows[0])
    w = csv.DictWriter(file, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow({k: (f"{v:.4f}" if isinstance(v, float) else v)
                    for k, v in r.items()})
    return ""


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, time.time() - t0
