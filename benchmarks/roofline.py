"""§Roofline table: aggregate the dry-run JSONs into the per-(arch x shape x
mesh) report — three terms in seconds, dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs, and the step-time bound.

    python -m benchmarks.roofline [--dir experiments/dryrun] [--csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


COLS = ("arch", "shape", "mesh", "accum", "compute_s", "memory_s",
        "collective_s", "dcn_s", "bottleneck", "step_bound_s",
        "roofline_fraction", "useful_flops_ratio", "fits_16g")


def load(dirname: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rep = json.load(f)
        if "error" in rep:
            rows.append({"arch": rep["arch"], "shape": rep["shape"],
                         "mesh": rep["mesh"], "error": rep["error"]})
            continue
        row = {
            "arch": rep["arch"], "shape": rep["shape"], "mesh": rep["mesh"],
            "accum": rep.get("accum"), "fits_16g": rep.get("fits_16g"),
        }
        rl = rep.get("roofline", {})
        row.update({k: rl.get(k) for k in (
            "compute_s", "memory_s", "collective_s", "dcn_s", "bottleneck",
            "step_bound_s", "roofline_fraction")})
        row["useful_flops_ratio"] = rep.get("useful_flops_ratio")
        rows.append(row)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    if not rows:
        print(f"no dry-run reports in {args.dir}; run "
              "`python -m repro.launch.dryrun --all` first")
        return
    sep = " | " if args.markdown else ","
    print(sep.join(COLS))
    if args.markdown:
        print(sep.join(["---"] * len(COLS)))
    for r in rows:
        if "error" in r:
            print(sep.join([str(r.get("arch")), str(r.get("shape")),
                            str(r.get("mesh")), "ERROR", r["error"][:60]]))
            continue
        vals = []
        for c in COLS:
            v = r.get(c)
            vals.append(f"{v:.4g}" if isinstance(v, float) else str(v))
        print(sep.join(vals))


if __name__ == "__main__":
    main()
