"""GShard-style mixture-of-experts FFN (grouped top-k dispatch, pure JAX).

Routing is done over fixed-size token groups (cfg.moe_group) so the expert
capacity C = group * k * capacity_factor / E stays small and the dispatch /
combine einsums cost ~k*factor*E*C/(3*F) of the expert FFN itself (a few
percent) instead of scaling with sequence length.  Tokens over capacity are
dropped (standard "dropped" MoE); the auxiliary load-balancing loss keeps
the router near-uniform so drops are rare.

Expert parallelism: the dispatched activations [E, Gn, C, D] are sharded on
E over "model" when E divides the axis (llama4-scout: 16 experts); otherwise
expert weights are sharded FSDP(D-dim over "data") x TP(F-dim over "model")
and every chip computes all experts for its own tokens (granite-moe: 40
experts).  The choice is made by the sharding rules at trace time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

__all__ = ["init_moe", "apply_moe", "expert_capacity"]


def expert_capacity(cfg: ModelConfig, group: int) -> int:
    c = group * cfg.experts_per_token * cfg.moe_capacity_factor
    c = int(-(-c // cfg.num_experts))
    return max(4, min(c, group))


def init_moe(key: jax.Array, cfg: ModelConfig, num_layers: int) -> dict:
    """Stacked-on-L expert parameters."""
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    dt = cfg.param_dtype
    return {
        "router": jax.random.normal(ks[0], (num_layers, d, e), dt) * 0.02,
        "we_gate": jax.random.normal(ks[1], (num_layers, e, d, f), dt) * scale_in,
        "we_up": jax.random.normal(ks[2], (num_layers, e, d, f), dt) * scale_in,
        "we_down": jax.random.normal(ks[3], (num_layers, e, f, d), dt) * scale_out,
    }


def _top_k_dispatch(probs: jax.Array, k: int, capacity: int):
    """probs: [Gn, G, E] router probabilities.

    Returns (dispatch [Gn, G, E, C] one-hot, combine [Gn, G, E, C] weighted,
    aux load-balance loss scalar).  Position-in-expert assignment is the
    standard iterative top-k cumsum (GShard algorithm 1)."""
    gn, g, e = probs.shape
    remaining = probs
    # running token count already assigned per (group, expert)
    fill = jnp.zeros((gn, e), jnp.int32)
    dispatch = jnp.zeros((gn, g, e, capacity), probs.dtype)
    combine = jnp.zeros((gn, g, e, capacity), probs.dtype)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)                  # [Gn, G]
        onehot = jax.nn.one_hot(idx, e, dtype=probs.dtype)    # [Gn, G, E]
        gate = (remaining * onehot).sum(-1)                   # [Gn, G]
        # position of each token within its chosen expert's buffer
        pos = jnp.cumsum(onehot, axis=1) - onehot + fill[:, None, :]
        pos_tok = (pos * onehot).sum(-1).astype(jnp.int32)    # [Gn, G]
        keep = pos_tok < capacity
        slot = jax.nn.one_hot(jnp.where(keep, pos_tok, capacity),
                              capacity + 1, dtype=probs.dtype)[..., :capacity]
        sel = onehot[..., None] * slot[:, :, None, :]         # [Gn,G,E,C]
        dispatch = dispatch + sel
        combine = combine + sel * gate[:, :, None, None]
        fill = fill + (onehot * keep[..., None]).sum(axis=1).astype(jnp.int32)
        remaining = remaining * (1.0 - onehot)
    return dispatch, combine


def _aux_loss(probs: jax.Array, dispatch: jax.Array) -> jax.Array:
    """Load-balancing loss: E * sum_e mean_prob_e * mean_assigned_frac_e."""
    e = probs.shape[-1]
    mean_prob = probs.mean(axis=(0, 1))                       # [E]
    frac = dispatch.sum(axis=-1).mean(axis=(0, 1))            # [E]
    return e * (mean_prob * frac).sum()


def apply_moe(cfg: ModelConfig, x: jax.Array, router_w: jax.Array,
              we_gate: jax.Array, we_up: jax.Array, we_down: jax.Array,
              shard: layers.Shard) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    group = min(cfg.moe_group, t)
    while t % group != 0:           # static: shapes are compile-time
        group //= 2
    gn = t // group
    cap = expert_capacity(cfg, group)
    xg = x.reshape(gn, group, d)
    xg = shard(xg, "moe_tokens")

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    dispatch, combine = _top_k_dispatch(probs, cfg.experts_per_token, cap)
    aux = _aux_loss(probs, dispatch)

    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)
    # dispatch tokens into per-expert buffers: [E, Gn, C, D]
    xe = jnp.einsum("gtec,gtd->egcd", dispatch, xg)
    xe = shard(xe, "moe_experts")
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, we_gate.astype(x.dtype)))
    h = h * jnp.einsum("egcd,edf->egcf", xe, we_up.astype(x.dtype))
    ye = jnp.einsum("egcf,efd->egcd", h, we_down.astype(x.dtype))
    ye = shard(ye, "moe_experts")
    # combine back to token order with gate weights
    out = jnp.einsum("gtec,egcd->gtd", combine, ye)
    out = shard(out, "moe_tokens")
    return out.reshape(b, s, d), aux.astype(jnp.float32)
