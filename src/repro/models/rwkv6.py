"""RWKV-6 "Finch": attention-free time mixing with data-dependent decay.

Time mixing per head (head_dim n): state S in R^{n x n},

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t

with w_t = exp(-exp(d_t)) a *data-dependent* per-channel decay (the Finch
contribution), d_t from a low-rank projection of the token-shifted input.

Training/prefill use the chunked formulation (flash-linear-attention style):
within a chunk of 32 tokens the interaction is a masked quadratic form with
decay weights, across chunks a lax.scan carries S.  All decay exponents are
clamped to 2.5/step so every exp() stays inside float32 range for a 32-token
chunk (|cum log w| <= 80 < log(3.4e38)); the clamp changes nothing in
practice since exp(-2.5) per step is already ~forgotten in 3 tokens.
This mirrors the Pallas kernel tiling in repro.kernels.wkv.

Decode is the O(1) recurrence — no KV cache, which is why rwkv6 runs the
500k-token decode shape.

Simplification vs the full Finch block (noted in DESIGN.md): token-shift
lerp coefficients are learned but static (the low-rank *data-dependent*
part is kept only for the decay d_t, which is the paper-relevant feature).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, transformer as tfm
from repro.models.config import ModelConfig

__all__ = ["init_params", "forward", "prefill", "decode_step", "init_cache",
           "CHUNK", "LOG_W_CLAMP"]

CHUNK = 32
LOG_W_CLAMP = 2.5     # max |log w| per step (see module docstring)
LORA_R = 64


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    d, f, nl = cfg.d_model, cfg.d_ff, cfg.num_layers
    vp = cfg.padded_vocab
    pdt = jnp.dtype(cfg.param_dtype)
    ks = iter(jax.random.split(key, 16))

    def mat(k, *shape, fan_in):
        return jax.random.normal(k, shape, pdt) / jnp.sqrt(fan_in)

    blocks = {
        "ln1": jnp.ones((nl, d), pdt),
        "ln2": jnp.ones((nl, d), pdt),
        # token-shift lerp coefficients (static): r, k, v, g, w | k2, r2
        "mu": jnp.full((nl, 7, d), 0.5, pdt),
        "w_r": mat(next(ks), nl, d, d, fan_in=d),
        "w_k": mat(next(ks), nl, d, d, fan_in=d),
        "w_v": mat(next(ks), nl, d, d, fan_in=d),
        "w_g": mat(next(ks), nl, d, d, fan_in=d),
        "w_o": mat(next(ks), nl, d, d, fan_in=d),
        "decay_base": jnp.full((nl, d), -0.6, pdt),   # exp(-exp(-0.6))~0.58
        "decay_a": mat(next(ks), nl, d, LORA_R, fan_in=d),
        "decay_b": jnp.zeros((nl, LORA_R, d), pdt),
        "bonus": jnp.zeros((nl, d), pdt),             # u
        "ln_x": jnp.ones((nl, d), pdt),               # per-head norm gain
        # channel mixing
        "wk2": mat(next(ks), nl, d, f, fan_in=d),
        "wv2": mat(next(ks), nl, f, d, fan_in=f),
        "wr2": mat(next(ks), nl, d, d, fan_in=d),
    }
    return {
        "emb": mat(next(ks), vp, d, fan_in=1.0) * 0.02,
        "head": mat(next(ks), d, vp, fan_in=d),
        "final_norm": jnp.ones((d,), pdt),
        "blocks": blocks,
    }


# --------------------------------------------------------------------------
# pieces
# --------------------------------------------------------------------------

def _shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x_{t-1} along the seq axis; ``prev`` [B, D] seeds t=0 (decode)."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def _rkvgw(cfg: ModelConfig, x, x_prev, lw):
    """Projections for time mixing.  Returns r,k,v [B,T,H,n] f32,
    g [B,T,D], log_w [B,T,H,n] f32 (negative)."""
    h = cfg.num_rwkv_heads
    n = cfg.rwkv_head_dim
    b, t, d = x.shape
    mu = lw["mu"]
    xr, xk, xv, xg, xw = (_lerp(x, x_prev, mu[i]) for i in range(5))
    r = layers.dense(xr, lw["w_r"]).astype(jnp.float32).reshape(b, t, h, n)
    k = layers.dense(xk, lw["w_k"]).astype(jnp.float32).reshape(b, t, h, n)
    v = layers.dense(xv, lw["w_v"]).astype(jnp.float32).reshape(b, t, h, n)
    g = jax.nn.silu(layers.dense(xg, lw["w_g"]))
    dlow = jnp.tanh(layers.dense(xw, lw["decay_a"]).astype(jnp.float32))
    dd = lw["decay_base"].astype(jnp.float32) + dlow @ lw["decay_b"].astype(jnp.float32)
    log_w = -jnp.clip(jnp.exp(dd), 1e-6, LOG_W_CLAMP).reshape(b, t, h, n)
    return r, k, v, g, log_w


def _wkv_chunked(r, k, v, log_w, u, s0):
    """Chunked WKV.  r,k,v,log_w: [B,T,H,n] f32; u: [H,n]; s0: [B,H,n,n].
    Returns (o [B,T,H,n], s_final)."""
    b, t, h, n = r.shape
    nc = t // CHUNK
    resh = lambda x: x.reshape(b, nc, CHUNK, h, n).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = map(resh, (r, k, v, log_w))      # [NC,B,H,C,n]

    tri_s = jnp.tril(jnp.ones((CHUNK, CHUNK), bool), k=-1)  # strictly lower

    def chunk(s, inp):
        rr, kk, vv, ww = inp                          # [B,H,C,n]
        lcw = jnp.cumsum(ww, axis=2)                  # inclusive
        lcw_ex = lcw - ww                             # exclusive
        r_t = rr * jnp.exp(lcw_ex)                    # decay to chunk start
        k_t = kk * jnp.exp(-lcw)                      # bounded by CHUNK clamp
        a = jnp.einsum("bhtn,bhin->bhti", r_t, k_t)
        a = jnp.where(tri_s[None, None], a, 0.0)
        diag = jnp.einsum("bhtn,bhtn->bht", rr * u[None, :, None, :], kk)
        o = jnp.einsum("bhti,bhin->bhtn", a, vv)
        o = o + diag[..., None] * vv
        o = o + jnp.einsum("bhtn,bhnm->bhtm", r_t, s)
        total = lcw[:, :, -1:]                        # [B,H,1,n]
        k_s = kk * jnp.exp(total - lcw)
        s_new = s * jnp.exp(total.squeeze(2))[..., None] + \
            jnp.einsum("bhtn,bhtm->bhnm", k_s, vv)
        return s_new, o

    s, o = layers.scan(chunk, s0, (rc, kc, vc, wc))
    o = o.transpose(1, 0, 3, 2, 4).reshape(b, t, h, n)
    return o, s


def _wkv_step(r, k, v, log_w, u, s):
    """One-token WKV.  r,k,v,log_w [B,1,H,n]; s [B,H,n,n]."""
    rr, kk, vv, ww = (x[:, 0] for x in (r, k, v, log_w))   # [B,H,n]
    o = jnp.einsum("bhn,bhnm->bhm", rr, s) + \
        jnp.einsum("bhn,bhn,bhm->bhm", rr * u, kk, vv)
    s_new = s * jnp.exp(ww)[..., None] + \
        jnp.einsum("bhn,bhm->bhnm", kk, vv)
    return o[:, None], s_new


def _head_norm(cfg: ModelConfig, o: jax.Array, gain: jax.Array) -> jax.Array:
    """Per-head layernorm of the WKV output (RWKV's GroupNorm)."""
    mean = o.mean(-1, keepdims=True)
    var = o.var(-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + 1e-5)
    b, t = o.shape[:2]
    return o.reshape(b, t, cfg.d_model) * gain.astype(o.dtype)


def _time_mix(cfg, x, lw, shard, prev, s0):
    u = lw["bonus"].astype(jnp.float32).reshape(cfg.num_rwkv_heads,
                                                cfg.rwkv_head_dim)
    x_prev = _shift(x, prev)
    r, k, v, g, log_w = _rkvgw(cfg, x, x_prev, lw)
    r = shard(r, "heads")
    k = shard(k, "heads")
    if x.shape[1] == 1:
        o, s = _wkv_step(r, k, v, log_w, u, s0)
    else:
        t = x.shape[1]
        if t % CHUNK:
            pad = CHUNK - t % CHUNK
            r, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                       for a in (r, k, v))
            log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))
            o, s = _wkv_chunked(r, k, v, log_w, u, s0)
            o = o[:, :t]
        else:
            o, s = _wkv_chunked(r, k, v, log_w, u, s0)
    o = shard(o.astype(x.dtype), "heads")
    o = _head_norm(cfg, o, lw["ln_x"]) * g
    out = layers.dense(o, lw["w_o"])
    return shard(out, "act_btd"), x[:, -1], s


def _channel_mix(cfg, x, lw, shard, prev):
    x_prev = _shift(x, prev)
    xk = _lerp(x, x_prev, lw["mu"][5])
    xr = _lerp(x, x_prev, lw["mu"][6])
    kk = jnp.square(jax.nn.relu(layers.dense(xk, lw["wk2"])))
    kk = shard(kk, "ffn_hidden")
    out = jax.nn.sigmoid(layers.dense(xr, lw["wr2"])) * \
        layers.dense(kk, lw["wv2"])
    return shard(out, "act_btd"), x[:, -1]


def _block(cfg, x, lw, shard, cache):
    s0 = cache["s"] if cache else jnp.zeros(
        (x.shape[0], cfg.num_rwkv_heads, cfg.rwkv_head_dim,
         cfg.rwkv_head_dim), jnp.float32)
    prev1 = cache["shift1"] if cache else None
    prev2 = cache["shift2"] if cache else None
    h = layers.rms_norm(x, lw["ln1"], cfg.norm_eps)
    a, last1, s = _time_mix(cfg, h, lw, shard, prev1, s0)
    x = x + a
    h = layers.rms_norm(x, lw["ln2"], cfg.norm_eps)
    c, last2 = _channel_mix(cfg, h, lw, shard, prev2)
    x = x + c
    return x, {"s": s, "shift1": last1, "shift2": last2}


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, batch: dict,
            shard: layers.Shard = layers.no_shard, collect_cache: bool = False,
            unembed: bool = True):
    x = tfm._embed(cfg, params, batch, shard)

    def body(x, lw):
        x, c = _block(cfg, x, lw, shard, None)
        return x, (c if collect_cache else None)

    x, caches = layers.scan(
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable),
        x, params["blocks"])
    if not unembed:
        x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.float32(0.0), caches
    logits = tfm._unembed(cfg, params, x, shard)
    return logits, jnp.float32(0.0), caches


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    del max_len              # O(1) state — the reason rwkv6 runs long_500k
    h, n, nl, d = (cfg.num_rwkv_heads, cfg.rwkv_head_dim, cfg.num_layers,
                   cfg.d_model)
    dt = jnp.dtype(cfg.dtype)
    return {
        "s": jnp.zeros((nl, batch_size, h, n, n), jnp.float32),
        "shift1": jnp.zeros((nl, batch_size, d), dt),
        "shift2": jnp.zeros((nl, batch_size, d), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_len: int,
            shard: layers.Shard = layers.no_shard):
    logits, _, caches = forward(cfg, params, batch, shard, collect_cache=True)
    cache = {"s": caches["s"], "shift1": caches["shift1"],
             "shift2": caches["shift2"],
             "pos": jnp.int32(batch["tokens"].shape[1])}
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, shard: layers.Shard = layers.no_shard):
    x = tfm._embed(cfg, params, {"tokens": tokens}, shard)

    def body(x, scanned):
        lw, s, sh1, sh2 = scanned
        x, c = _block(cfg, x, lw, shard,
                      {"s": s, "shift1": sh1, "shift2": sh2})
        return x, c

    x, caches = layers.scan(
        body, x, (params["blocks"], cache["s"], cache["shift1"],
                  cache["shift2"]))
    logits = tfm._unembed(cfg, params, x, shard)
    return logits[:, -1], {"s": caches["s"], "shift1": caches["shift1"],
                           "shift2": caches["shift2"],
                           "pos": cache["pos"] + 1}
