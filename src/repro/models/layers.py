"""Core NN layers shared by every assigned architecture (pure JAX).

Everything here is shape-polymorphic over the config and carries explicit
sharding hooks: the caller passes a ``shard`` callable (activation name ->
with_sharding_constraint) so the same code runs unsharded on one CPU device
(tests) and fully partitioned on the production mesh (dry-run / TPU).

Attention is blockwise with an online softmax (FlashAttention recurrence in
pure jnp): the O(Lq*Lk) score matrix is never materialised, only
[.., Lq, block] panels, so the XLA memory profile matches the Pallas kernel
(repro.kernels.flash_attention) that replaces it on real TPU hardware.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Shard", "no_shard", "rms_norm", "rope", "m_rope", "apply_rope",
    "attention", "swiglu", "dense", "init_dense", "init_rms",
]

Shard = Callable[[jax.Array, str], jax.Array]


def no_shard(x: jax.Array, name: str) -> jax.Array:   # noqa: ARG001
    return x


# --------------------------------------------------------------------------
# scan-unroll context (roofline cost probes)
#
# XLA's HloCostAnalysis counts a while-loop body ONCE, ignoring the trip
# count (verified empirically), so FLOP/byte accounting of scanned programs
# is wrong by ~num_layers.  The dry-run cost probes therefore lower small
# fully-unrolled variants under this context and extrapolate linearly in
# (num_layers, accum); production lowering keeps scans rolled.
# --------------------------------------------------------------------------

import contextlib as _contextlib

_UNROLL_SCANS = False


def scan_unroll() -> bool | int:
    return True if _UNROLL_SCANS else 1


def scan(body, init, xs, **kw):
    """jax.lax.scan that honours the unroll context."""
    return jax.lax.scan(body, init, xs, unroll=scan_unroll(), **kw)


@_contextlib.contextmanager
def unrolled_scans():
    global _UNROLL_SCANS
    prev = _UNROLL_SCANS
    _UNROLL_SCANS = True
    try:
        yield
    finally:
        _UNROLL_SCANS = prev


# --------------------------------------------------------------------------
# initialisers / tiny layers
# --------------------------------------------------------------------------

def init_dense(key: jax.Array, d_in: int, d_out: int,
               dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def init_rms(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, shard: Shard = no_shard) -> jax.Array:
    h = jax.nn.silu(dense(x, w_gate)) * dense(x, w_up)
    h = shard(h, "ffn_hidden")
    return dense(h, w_down)


# --------------------------------------------------------------------------
# rotary embeddings (standard + multimodal M-RoPE)
# --------------------------------------------------------------------------

def rope(positions: jax.Array, head_dim: int,
         theta: float = 1e4) -> tuple[jax.Array, jax.Array]:
    """positions [..., L] -> (sin, cos) of shape [..., L, head_dim//2]."""
    freq = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.sin(ang), jnp.cos(ang)


def m_rope(positions: jax.Array, head_dim: int, sections: tuple[int, ...],
           theta: float = 1e4) -> tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE.  positions: [B, 3, L] (t, h, w component
    ids); ``sections`` splits head_dim//2 frequency slots across the three
    components (e.g. (16, 24, 24) for head_dim 128)."""
    assert positions.ndim >= 2 and positions.shape[-2] == len(sections)
    half = head_dim // 2
    assert sum(sections) == half, (sections, head_dim)
    freq = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))
    # component id per frequency slot: first sections[0] slots use t, etc.
    comp = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                      total_repeat_length=half)                     # [half]
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        comp[None, :, None].repeat(positions.shape[0], 0), axis=1)  # [B,half,L]
    ang = pos.transpose(0, 2, 1) * freq[None, None, :]              # [B,L,half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: [B, L, H, D]; sin/cos: [L, D/2] or [B, L, D/2] (broadcast over H)."""
    if sin.ndim == 2:
        sin, cos = sin[None], cos[None]
    sin, cos = sin[:, :, None, :], cos[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([
        x1 * cos - x2 * sin,
        x2 * cos + x1 * sin,
    ], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# blockwise attention (GQA, causal / local-window, decode-friendly)
# --------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              q_offset: int | jax.Array = 0,
              kv_len: int | jax.Array | None = None,
              window: int = 0,
              block: int = 1024,
              shard: Shard = no_shard) -> jax.Array:
    """Online-softmax blockwise GQA attention.

    q: [B, Lq, Hq, D]; k, v: [B, Lk, Hkv, D] with Hq % Hkv == 0.
    q_offset: absolute position of q[0] (decode: current pos).
    kv_len:   number of valid cache positions (decode: pos + 1).
    window:   if > 0, local attention over the last ``window`` key positions.
    Scores are computed one key-block at a time; the running max/normaliser
    recurrence matches FlashAttention (and the Pallas kernel bit-for-bit up
    to float addition order).
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / np.sqrt(d)
    if (window > 0 and causal and lq == lk and lq > window
            and isinstance(q_offset, int) and q_offset == 0):
        return _attention_banded(q, k, v, window=window, block=block,
                                 shard=shard)
    nblocks = max(1, -(-lk // block))
    blk = min(block, lk)
    pad = nblocks * blk - lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = q.astype(jnp.float32).reshape(b, lq, hkv, g, d)
    # shard the attention internals on the QUERY-SEQ dim and replicate the
    # (small, GQA) k/v: the (Hkv, g) reshape of the head dim misaligns with
    # head sharding whenever heads/|model| is not a multiple of g, which
    # makes GSPMD replicate the f32 score panels (measured: 51 GB of
    # all-gathers in a 2-layer mistral probe — perf iteration 5).  Seq
    # sharding keeps every panel local; k/v are [B, Lk, Hkv, D] bf16.
    qf = shard(qf, "attn_q_seq")
    k = shard(k, "attn_kv_rep")
    v = shard(v, "attn_kv_rep")
    q_pos = q_offset + jnp.arange(lq)                         # [Lq]
    valid_k = jnp.asarray(lk if kv_len is None else kv_len)

    def body(carry, kb):
        acc, m, l, start = carry
        kc, vc = kb                                           # [B, blk, Hkv, D]
        kpos = start + jnp.arange(blk)                        # [blk]
        s = jnp.einsum("blhgd,bkhd->bhglk", qf,
                       kc.astype(jnp.float32)) * scale        # [B,Hkv,g,Lq,blk]
        mask = (kpos[None, :] < valid_k)
        if causal:
            mask = mask & (kpos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (kpos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> nan
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhglk,bkhd->bhgld", p, vc.astype(jnp.float32))
        return (acc, m_new, l, start + blk), None

    acc0 = jnp.zeros((b, hkv, g, lq, d), jnp.float32)
    m0 = jnp.full((b, hkv, g, lq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, lq), jnp.float32)
    if nblocks == 1:
        (acc, _, l, _), _ = body((acc0, m0, l0, jnp.int32(0)), (k, v))
    else:
        kb = k.reshape(b, nblocks, blk, hkv, d).transpose(1, 0, 2, 3, 4)
        vb = v.reshape(b, nblocks, blk, hkv, d).transpose(1, 0, 2, 3, 4)
        (acc, _, l, _), _ = scan(
            body, (acc0, m0, l0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]              # [B,Hkv,g,Lq,D]
    out = shard(out, "attn_acc_seq")
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, lq, hq, d)
    return shard(out.astype(q.dtype), "attn_out")


def _attention_banded(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      window: int, block: int,
                      shard: Shard = no_shard) -> jax.Array:
    """Local-window causal self-attention as a scan over query blocks, each
    attending to a STATIC (window + block)-long kv slice ending at its own
    last position.  Compute drops from O(L^2) to O(L*(window+block)) —
    10.7x fewer attention FLOPs for the 2048-window hybrid at 32k prefill
    (perf iteration 2; the full-L^2 blockwise path only masked the band).
    """
    b, l, hq, d = q.shape
    _, _, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / np.sqrt(d)
    blk = min(block, l)
    pad_q = (-l) % blk
    span = min(window + blk, l + pad_q)
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nq = (l + pad_q) // blk

    def qblock(_, i):
        q_start = i * blk
        qs = jax.lax.dynamic_slice_in_dim(q, q_start, blk, 1)
        start = jnp.clip(q_start + blk - span, 0, l + pad_q - span)
        ks = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
        qf = qs.astype(jnp.float32).reshape(b, blk, hkv, g, d)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                       ks.astype(jnp.float32)) * scale
        qpos = q_start + jnp.arange(blk)
        kpos = start + jnp.arange(span)
        mask = (kpos[None, :] <= qpos[:, None]) & \
               (kpos[None, :] > qpos[:, None] - window) & \
               (kpos[None, :] < l)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        # every q row has at least its own key in range -> softmax is safe
        p = jnp.exp(s - jax.lax.stop_gradient(s.max(-1, keepdims=True)))
        p = jnp.where(mask[None, None, None], p, 0.0)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p, vs.astype(jnp.float32))
        o = o / jnp.maximum(p.sum(-1), 1e-30)[..., None]
        return None, o                                   # [B,Hkv,g,blk,D]

    _, blocks = scan(qblock, None, jnp.arange(nq))       # [nq,B,Hkv,g,blk,D]
    out = blocks.transpose(1, 0, 4, 2, 3, 5)             # [B,nq,blk,Hkv,g,D]
    out = out.reshape(b, nq * blk, hq, d)[:, :l]
    return shard(out.astype(q.dtype), "attn_out")
