"""Architecture registry + loss + train/serve step builders.

Families dispatch to their module (transformer covers dense/moe/vlm/audio;
rglru covers the Griffin hybrid; rwkv6 the attention-free SSM), all exposing
the same API: init_params / forward / prefill / decode_step / init_cache.

Steps are built as pure functions of (params, opt_state, batch) so they jit
and lower identically on a 1-device test mesh and the 512-chip production
mesh; all sharding flows through the ``shard`` callable and the in/out
shardings attached by the launcher.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import layers, rglru, rwkv6, transformer as tfm
from repro.models.config import ModelConfig

__all__ = ["Model", "get_model", "cross_entropy", "make_train_step",
           "make_prefill_step", "make_decode_step"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], dict]
    forward: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[[int, int], dict]


def get_model(cfg: ModelConfig) -> Model:
    mod = {"hybrid": rglru, "ssm": rwkv6}.get(cfg.family, tfm)
    return Model(
        cfg=cfg,
        init_params=lambda key: mod.init_params(key, cfg),
        forward=lambda params, batch, shard=layers.no_shard, **kw: mod.forward(
            cfg, params, batch, shard, **kw),
        prefill=lambda params, batch, max_len, shard=layers.no_shard:
            mod.prefill(cfg, params, batch, max_len, shard),
        decode_step=lambda params, cache, tokens, shard=layers.no_shard:
            mod.decode_step(cfg, params, cache, tokens, shard),
        init_cache=lambda batch_size, max_len: mod.init_cache(
            cfg, batch_size, max_len),
    )


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def cross_entropy(cfg: ModelConfig, logits: jax.Array,
                  labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mean next-token CE over valid positions (labels < 0 are masked, e.g.
    VLM patch-prefix positions).  Padded vocab columns are masked to -inf so
    the padding never changes the distribution."""
    vp = logits.shape[-1]
    col_ok = jnp.arange(vp) < cfg.vocab_size
    lg = jnp.where(col_ok, logits.astype(jnp.float32), -1e9)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(
        lg, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    n = jnp.maximum(mask.sum(), 1.0)
    loss = ((lse - ll) * mask).sum() / n
    return loss, n


def chunked_cross_entropy(cfg: ModelConfig, head: jax.Array, x: jax.Array,
                          labels: jax.Array, shard: layers.Shard,
                          chunk: int = 512) -> jax.Array:
    """Fused unembed + CE, scanned over sequence chunks with remat: the full
    [B, S, V] logits are never live (a [B, chunk, V] panel is), which is
    what keeps the 150k-256k-vocab archs inside HBM during training."""
    b, s, d = x.shape
    c = min(chunk, s)
    while s % c:
        c //= 2
    nc = s // c
    xc = x.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)
    col_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size

    def body(carry, xl):
        loss_sum, n_sum = carry
        xch, lch = xl
        logits = jnp.einsum("bsd,dv->bsv", xch, head.astype(xch.dtype))
        logits = shard(logits, "logits")
        lg = jnp.where(col_ok, logits.astype(jnp.float32), -1e9)
        lse = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(
            lg, jnp.maximum(lch, 0)[..., None], axis=-1)[..., 0]
        mask = (lch >= 0).astype(jnp.float32)
        return (loss_sum + ((lse - ll) * mask).sum(),
                n_sum + mask.sum()), None

    (loss_sum, n), _ = layers.scan(
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable),
        (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc))
    return loss_sum / jnp.maximum(n, 1.0)


def _loss_fn(cfg: ModelConfig, model: Model, params: dict, batch: dict,
             shard: layers.Shard, aux_weight: float = 0.01):
    x, aux, _ = model.forward(params, batch, shard, unembed=False)
    loss = chunked_cross_entropy(cfg, params["head"], x, batch["labels"],
                                 shard)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux}


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer,
                    shard: layers.Shard = layers.no_shard,
                    accum: int = 1,
                    pod_compress: bool = False, npod: int = 1,
                    unshard_pod=None):
    """Returns train_step(params, opt_state, batch) -> (params', opt_state',
    metrics).  ``batch`` leaves are [accum, micro_batch, ...]; gradients are
    accumulated over the leading dim with a lax.scan (each microbatch is
    rematerialised, so live activation memory is one microbatch).

    pod_compress: int8 error-feedback compression of the cross-pod gradient
    hop (optim.compress).  Gradients are computed per-pod by vmapping over a
    leading pod dim (the microbatch is reshaped [B] -> [npod, B/npod]); the
    only cross-pod collective is then the int8 all-gather inside
    ef_compress_mean.  Requires an extra "ef_error" buffer in opt_state (use
    init_ef_error) and a ``shard`` built with dp_axes=("data",).
    """
    model = get_model(cfg)
    grad_fn = jax.value_and_grad(
        lambda p, b: _loss_fn(cfg, model, p, b, shard), has_aux=True)

    def per_pod_grad(params, mb):
        mb = jax.tree.map(
            lambda x: x.reshape((npod, x.shape[0] // npod) + x.shape[1:]), mb)
        (_, metrics), g = jax.vmap(
            lambda b: grad_fn(params, b))(mb)          # leading dim: pod
        return metrics, g

    def train_step(params, opt_state, batch):
        def micro(carry, mb):
            g_acc, metrics_acc = carry
            if pod_compress:
                metrics, g = per_pod_grad(params, mb)
                metrics = jax.tree.map(lambda m: m.mean(), metrics)
            else:
                (_, metrics), g = grad_fn(params, mb)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            metrics_acc = jax.tree.map(jnp.add, metrics_acc, metrics)
            return (g_acc, metrics_acc), None

        def gzeros(p):
            shape = (npod,) + p.shape if pod_compress else p.shape
            return jnp.zeros(shape, jnp.float32)

        g0 = jax.tree.map(gzeros, params)
        m0 = {"loss": jnp.float32(0.0), "aux_loss": jnp.float32(0.0)}
        if accum == 1:
            (grads, metrics), _ = micro((g0, m0),
                                        jax.tree.map(lambda x: x[0], batch))
        else:
            (grads, metrics), _ = layers.scan(micro, (g0, m0), batch)
        grads = jax.tree.map(lambda g: g / accum, grads)
        metrics = jax.tree.map(lambda m: m / accum, metrics)
        if pod_compress:
            from repro.optim import compress as _compress
            grads, new_err = _compress.ef_compress_mean(
                grads, opt_state["ef_error"], npod, unshard_pod)
            opt_state = dict(opt_state, ef_error=new_err)
        gnorm = optimizer.global_norm(grads)
        inner = {k: v for k, v in opt_state.items() if k != "ef_error"}
        params, new_inner = optimizer.update(params, grads, inner)
        if pod_compress:
            opt_state = dict(new_inner, ef_error=opt_state["ef_error"])
        else:
            opt_state = new_inner
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def init_ef_error(params, npod: int):
    """Error-feedback buffer for pod_compress (bf16, one row per pod)."""
    return jax.tree.map(
        lambda p: jnp.zeros((npod,) + p.shape, jnp.bfloat16), params)


def make_prefill_step(cfg: ModelConfig, max_len: int,
                      shard: layers.Shard = layers.no_shard):
    model = get_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len, shard)

    return prefill_step


def make_decode_step(cfg: ModelConfig,
                     shard: layers.Shard = layers.no_shard):
    model = get_model(cfg)

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens, shard)

    return decode_step
