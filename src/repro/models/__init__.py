from repro.models.config import ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    Model, get_model, cross_entropy, make_train_step, make_prefill_step,
    make_decode_step,
)
