"""Dense / MoE / VLM / audio decoder-only transformer (scan-over-layers).

One parameter pytree with every per-layer tensor stacked on a leading L dim
so the layer loop is a single jax.lax.scan (small HLO, fast SPMD partitioning
at 512 devices) with per-layer rematerialisation (only the seq-sharded
residual is saved between layers).

Attention weights are stored flat ([D, H*Dh]) so parameters always shard
evenly over the mesh; the reshape to heads happens inside the layer where
GSPMD may pad an uneven head count.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib
from repro.models.config import ModelConfig

__all__ = ["init_params", "forward", "prefill", "decode_step", "init_cache"]


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv, f, nl = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.num_layers
    vp = cfg.padded_vocab
    pdt = _pdt(cfg)
    ks = iter(jax.random.split(key, 24))

    def norm(*shape):
        return jnp.ones(shape, pdt)

    def mat(k, *shape, fan_in):
        return jax.random.normal(k, shape, pdt) / jnp.sqrt(fan_in)

    blocks: dict[str, Any] = {
        "ln1": norm(nl, d),
        "ln2": norm(nl, d),
        "wq": mat(next(ks), nl, d, hq * hd, fan_in=d),
        "wk": mat(next(ks), nl, d, hkv * hd, fan_in=d),
        "wv": mat(next(ks), nl, d, hkv * hd, fan_in=d),
        "wo": mat(next(ks), nl, hq * hd, d, fan_in=hq * hd),
    }
    if cfg.qkv_bias:
        blocks["bq"] = jnp.zeros((nl, hq * hd), pdt)
        blocks["bk"] = jnp.zeros((nl, hkv * hd), pdt)
        blocks["bv"] = jnp.zeros((nl, hkv * hd), pdt)
    if cfg.num_experts:
        blocks.update(moe_lib.init_moe(next(ks), cfg, nl))
    else:
        blocks["wg"] = mat(next(ks), nl, d, f, fan_in=d)
        blocks["wu"] = mat(next(ks), nl, d, f, fan_in=d)
        blocks["wd"] = mat(next(ks), nl, f, d, fan_in=f)

    params = {
        "emb": mat(next(ks), vp, d, fan_in=1.0) * 0.02,
        "head": mat(next(ks), d, vp, fan_in=d),
        "final_norm": norm(d),
        "blocks": blocks,
    }
    if cfg.frontend == "patch":
        params["w_patch"] = mat(next(ks), cfg.frontend_dim, d,
                                fan_in=cfg.frontend_dim)
    return params


# --------------------------------------------------------------------------
# shared block body
# --------------------------------------------------------------------------

def _attn_block(cfg: ModelConfig, x: jax.Array, lw: dict, sin, cos,
                shard: layers.Shard, *,
                kv_cache: tuple | None = None,
                q_offset=0, kv_len=None) -> tuple[jax.Array, tuple | None]:
    """Attention sub-block.  Full-seq when kv_cache is None (returns fresh
    k/v for cache construction); decode when kv_cache=(k_all, v_all, pos)."""
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    b, l, _ = x.shape

    h = layers.rms_norm(x, lw["ln1"], cfg.norm_eps)
    h = shard(h, "act_btd_full")
    wq = lw["wq"].astype(h.dtype).reshape(d, hq, hd)
    wk = lw["wk"].astype(h.dtype).reshape(d, hkv, hd)
    wv = lw["wv"].astype(h.dtype).reshape(d, hkv, hd)
    q = jnp.einsum("bsd,dhk->bshk", h, wq)
    k = jnp.einsum("bsd,dhk->bshk", h, wk)
    v = jnp.einsum("bsd,dhk->bshk", h, wv)
    if cfg.qkv_bias:
        q = q + lw["bq"].astype(h.dtype).reshape(hq, hd)
        k = k + lw["bk"].astype(h.dtype).reshape(hkv, hd)
        v = v + lw["bv"].astype(h.dtype).reshape(hkv, hd)
    q, k = layers.apply_rope(q, sin, cos), layers.apply_rope(k, sin, cos)
    q = shard(q, "heads")

    if kv_cache is None:
        k = shard(k, "heads")
        out = layers.attention(q, k, v, causal=True, q_offset=q_offset,
                               window=cfg.local_window, shard=shard)
        new_kv = (k, v)
    else:
        k_all, v_all, pos = kv_cache
        k_all = jax.lax.dynamic_update_slice(k_all, k.astype(k_all.dtype),
                                             (0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(v_all, v.astype(v_all.dtype),
                                             (0, pos, 0, 0))
        k_all = shard(k_all, "cache_kv")
        v_all = shard(v_all, "cache_kv")
        out = _attention_decode(q, k_all, v_all, kv_len=kv_len,
                                q_offset=q_offset, window=cfg.local_window)
        new_kv = (k_all, v_all)

    wo = lw["wo"].astype(h.dtype).reshape(hq, hd, d)
    out = jnp.einsum("bshk,hkd->bsd", out, wo)
    return shard(out, "act_btd"), new_kv


def _attention_decode(q, k, v, *, kv_len, q_offset, window=0):
    """Single-position attention over the full cache (flash-decoding: the
    cache seq dim is sharded over "model"; the max/sum reductions below
    become all-reduces over that axis)."""
    b, lq, hq, hd = q.shape
    _, smax, hkv, _ = k.shape
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, lq, hkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    kpos = jnp.arange(smax)
    mask = kpos[None, :] < kv_len
    if window > 0:
        mask = mask & (kpos[None, :] > (q_offset + jnp.arange(lq))[:, None]
                       - window)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, lq, hq, hd).astype(q.dtype)


def _ffn_block(cfg: ModelConfig, x: jax.Array, lw: dict,
               shard: layers.Shard) -> tuple[jax.Array, jax.Array]:
    h = layers.rms_norm(x, lw["ln2"], cfg.norm_eps)
    if cfg.num_experts:
        out, aux = moe_lib.apply_moe(cfg, h, lw["router"], lw["we_gate"],
                                     lw["we_up"], lw["we_down"], shard)
    else:
        out = layers.swiglu(h, lw["wg"].astype(h.dtype),
                            lw["wu"].astype(h.dtype),
                            lw["wd"].astype(h.dtype), shard)
        aux = jnp.float32(0.0)
    return shard(out, "act_btd"), aux


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params: dict, batch: dict,
           shard: layers.Shard) -> jax.Array:
    emb = params["emb"].astype(_dt(cfg))
    x = jnp.take(emb, batch["tokens"], axis=0)
    if cfg.frontend == "patch" and "patch_embeds" in batch:
        patches = batch["patch_embeds"].astype(_dt(cfg))
        px = jnp.einsum("bpf,fd->bpd", patches,
                        params["w_patch"].astype(_dt(cfg)))
        x = jnp.concatenate([px, x], axis=1)
    return shard(x, "act_btd")


def _unembed(cfg: ModelConfig, params: dict, x: jax.Array,
             shard: layers.Shard) -> jax.Array:
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
    return shard(logits, "logits")


def _rope_for(cfg: ModelConfig, batch: dict, seq_len: int, offset=0):
    # M-RoPE when per-component positions are supplied; for text-only decode
    # all three components are equal, which reduces exactly to standard RoPE.
    if cfg.mrope_sections is not None and "positions" in batch:
        return layers.m_rope(batch["positions"], cfg.head_dim_,
                             cfg.mrope_sections, cfg.rope_theta)
    pos = offset + jnp.arange(seq_len)
    return layers.rope(pos, cfg.head_dim_, cfg.rope_theta)


# --------------------------------------------------------------------------
# full-sequence forward (training / prefill)
# --------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, batch: dict,
            shard: layers.Shard = layers.no_shard,
            collect_kv: bool = False, unembed: bool = True):
    """Returns (logits [B, S, Vp], aux_loss, kv [L,B,S,Hkv,Dh]*2 | None).
    With unembed=False, returns the final-norm hidden states instead of
    logits (the loss then runs the seq-chunked fused unembed+CE, which never
    materialises the full [B, S, V] logits)."""
    x = _embed(cfg, params, batch, shard)
    seq_len = x.shape[1]
    sin, cos = _rope_for(cfg, batch, seq_len)

    def block(x, lw):
        a, kv = _attn_block(cfg, x, lw, sin, cos, shard)
        x = x + a
        f, aux = _ffn_block(cfg, x, lw, shard)
        x = x + f
        ys = (aux, kv) if collect_kv else (aux, None)
        return x, ys

    x, (auxs, kvs) = layers.scan(
        jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable),
        x, params["blocks"])
    if not unembed:
        x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, auxs.sum(), kvs
    logits = _unembed(cfg, params, x, shard)
    return logits, auxs.sum(), kvs


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    hd, hkv, nl = cfg.head_dim_, cfg.num_kv_heads, cfg.num_layers
    kv_shape = (nl, batch_size, max_len, hkv, hd)
    return {
        "k": jnp.zeros(kv_shape, _dt(cfg)),
        "v": jnp.zeros(kv_shape, _dt(cfg)),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_len: int,
            shard: layers.Shard = layers.no_shard):
    """Run the prompt through the model, build the cache, return the logits
    of the last position: (logits [B, Vp], cache)."""
    logits, _, (k, v) = forward(cfg, params, batch, shard, collect_kv=True)
    b, s = k.shape[1], k.shape[2]
    pad = max_len - s
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k, "v": v, "pos": jnp.int32(s)}
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array,
                shard: layers.Shard = layers.no_shard):
    """One token for every sequence: tokens [B, 1] -> (logits [B, Vp], cache).

    The stacked [L, ...] cache rides the scan CARRY and each layer writes its
    slice with dynamic_update_slice — XLA keeps one buffer updated in place.
    (Routing the cache through scan xs/ys instead double-buffers the whole
    thing: input xs + stacked ys both live, +2x cache bytes — measured on
    the mistral-123b decode_32k cell.)"""
    pos = cache["pos"]
    x = _embed(cfg, params, {"tokens": tokens}, shard)
    sin, cos = _rope_for(cfg, {"tokens": tokens}, 1, offset=pos)

    def block(carry, scanned):
        x, kc, vc, idx = carry
        lw = scanned
        k_l = jax.lax.dynamic_index_in_dim(kc, idx, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(vc, idx, 0, keepdims=False)
        a, (k_new, v_new) = _attn_block(
            cfg, x, lw, sin, cos, shard,
            kv_cache=(k_l, v_l, pos), q_offset=pos, kv_len=pos + 1)
        x = x + a
        f, _ = _ffn_block(cfg, x, lw, shard)
        kc = jax.lax.dynamic_update_index_in_dim(kc, k_new, idx, 0)
        vc = jax.lax.dynamic_update_index_in_dim(vc, v_new, idx, 0)
        return (x + f, kc, vc, idx + 1), None

    (x, k, v, _), _ = layers.scan(
        block, (x, cache["k"], cache["v"], jnp.int32(0)), params["blocks"])
    logits = _unembed(cfg, params, x, shard)
    return logits[:, -1], {"k": k, "v": v, "pos": pos + 1}
