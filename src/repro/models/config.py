"""Model configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int              # query heads (0 for attention-free archs)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_group: int = 1024              # tokens per routing group
    moe_capacity_factor: float = 1.25
    # --- hybrid (Griffin / RecurrentGemma) ----------------------------------
    block_pattern: tuple[str, ...] = ()   # cycle of "rec" | "attn"
    local_window: int = 0
    d_rnn: int = 0
    conv_width: int = 4
    # --- RWKV ----------------------------------------------------------------
    rwkv_head_dim: int = 64
    # --- modality frontend stubs ---------------------------------------------
    frontend: str = "none"             # none | patch (VLM) | frame (audio)
    frontend_dim: int = 0              # raw patch/frame embedding width
    frontend_len: int = 0              # prefix length supplied by the stub
    mrope_sections: tuple[int, int, int] | None = None
    # --- distribution ---------------------------------------------------------
    sharding_profile: str = "2d"       # "2d" (FSDP x TP + SP) | "fsdp"
    # --- numerics --------------------------------------------------------------
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "float32"

    # --- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Embedding/logit table rows padded to a multiple of 256 so the
        vocab dim shards evenly over any mesh axis <= 256 (padded logit
        columns are masked out in the loss and in sampling)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_rnn_(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def num_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer block kind; dense unless a block_pattern cycle is set."""
        if not self.block_pattern:
            return ("attn",) * self.num_layers
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def param_count(self) -> int:
        """Parameter count (embeddings + blocks) matching init_params; used
        for the roofline's MODEL_FLOPS = 6*N*D."""
        d, f = self.d_model, self.d_ff
        hd = self.head_dim_
        n = 2 * self.padded_vocab * d                    # emb + head (untied)
        for kind in self.layer_kinds:
            if kind == "rec":                            # Griffin RG-LRU block
                dr = self.d_rnn_
                n += 3 * d * dr + 2 * dr * dr            # in/out + gates
                n += self.conv_width * dr + 5 * dr       # conv + vectors
                n += 3 * d * f + 2 * d                   # MLP + norms
                continue
            if self.family == "ssm":                     # rwkv6 block
                n += 6 * d * d                           # w_r/k/v/g/o + wr2
                n += 2 * d * f                           # wk2, wv2
                n += 2 * 64 * d + 13 * d                 # decay lora + vectors
                continue
            n += d * (self.num_heads * hd)               # wq
            n += 2 * d * (self.num_kv_heads * hd)        # wk, wv
            n += (self.num_heads * hd) * d               # wo
            n += 2 * d
            if self.qkv_bias:
                n += (self.num_heads + 2 * self.num_kv_heads) * hd
            if self.num_experts:
                n += d * self.num_experts
                n += self.num_experts * 3 * d * f
            else:
                n += 3 * d * f
        n += d                                           # final norm
        if self.frontend == "patch":
            n += self.frontend_dim * d
        return n

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts top-k experts only."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_n = self.param_count() - len(self.layer_kinds) * (
            self.num_experts * 3 * d * f)
        return dense_n + len(self.layer_kinds) * (
            self.experts_per_token * 3 * d * f)
