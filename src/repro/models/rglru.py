"""Griffin-style hybrid: RG-LRU recurrent blocks + local attention
(RecurrentGemma-2B; block pattern cycles rec,rec,attn).

The RG-LRU recurrence

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * r_t * softplus(lambda))  in (0, 1)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

is a per-channel linear recurrence, so training/prefill run it with
jax.lax.associative_scan (log-depth, TPU-friendly); decode is the one-step
update.  The attention layers use a ring-buffer KV cache of the local window
(2048), which is what makes the 500k-token decode shape feasible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, transformer as tfm
from repro.models.config import ModelConfig

__all__ = ["init_params", "forward", "prefill", "decode_step", "init_cache"]

_C = 8.0   # RG-LRU decay sharpness constant (Griffin paper)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_rec_layer(key: jax.Array, cfg: ModelConfig) -> dict:
    d, dr, w = cfg.d_model, cfg.d_rnn_, cfg.conv_width
    ks = jax.random.split(key, 6)
    pdt = jnp.dtype(cfg.param_dtype)

    def mat(k, i, o):
        return jax.random.normal(k, (i, o), pdt) / jnp.sqrt(i)

    return {
        "ln1": jnp.ones((d,), pdt),
        "ln2": jnp.ones((d,), pdt),
        "w_gate_in": mat(ks[0], d, dr),     # GeLU gate branch
        "w_rnn_in": mat(ks[1], d, dr),      # conv -> RG-LRU branch
        "w_out": mat(ks[2], dr, d),
        "conv_w": jax.random.normal(ks[3], (w, dr), pdt) * 0.1,
        "conv_b": jnp.zeros((dr,), pdt),
        "w_a": mat(ks[4], dr, dr),
        "b_a": jnp.zeros((dr,), pdt),
        "w_x": mat(ks[5], dr, dr),
        "b_x": jnp.zeros((dr,), pdt),
        # lambda init so a^c is ~U(0.9, 0.999) at r=1 (Griffin appendix)
        "lam": jnp.full((dr,), 0.7, pdt),
        # MLP
        "wg": mat(ks[0], d, cfg.d_ff),
        "wu": mat(ks[1], d, cfg.d_ff),
        "wd": mat(ks[2], cfg.d_ff, d),
    }


def _init_attn_layer(key: jax.Array, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv, f = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff
    ks = jax.random.split(key, 7)
    pdt = jnp.dtype(cfg.param_dtype)

    def mat(k, i, o):
        return jax.random.normal(k, (i, o), pdt) / jnp.sqrt(i)

    return {
        "ln1": jnp.ones((d,), pdt),
        "ln2": jnp.ones((d,), pdt),
        "wq": mat(ks[0], d, hq * hd),
        "wk": mat(ks[1], d, hkv * hd),
        "wv": mat(ks[2], d, hkv * hd),
        "wo": mat(ks[3], hq * hd, d),
        "wg": mat(ks[4], d, f),
        "wu": mat(ks[5], d, f),
        "wd": mat(ks[6], f, d),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    kinds = cfg.layer_kinds
    keys = jax.random.split(key, cfg.num_layers + 2)
    blocks = [(_init_rec_layer if k == "rec" else _init_attn_layer)(kk, cfg)
              for k, kk in zip(kinds, keys[:-2])]
    pdt = jnp.dtype(cfg.param_dtype)
    vp = cfg.padded_vocab
    return {
        "emb": jax.random.normal(keys[-2], (vp, cfg.d_model), pdt) * 0.02,
        "head": jax.random.normal(keys[-1], (cfg.d_model, vp), pdt)
        / jnp.sqrt(cfg.d_model),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
        "blocks": blocks,
    }


# --------------------------------------------------------------------------
# RG-LRU + conv primitives
# --------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Per-channel causal conv.  x [B,T,D]; w [W,D].  Returns (y, new_state)
    where state is the last W-1 inputs."""
    width = w.shape[0]
    if state is None:
        hist = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        hist = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(hist[:, i:i + x.shape[1]] * w[width - 1 - i].astype(x.dtype)
            for i in range(width))
    return y + b.astype(x.dtype), hist[:, -(width - 1):]


def _rglru_gates(lw: dict, x: jax.Array):
    r = jax.nn.sigmoid(layers.dense(x, lw["w_a"], lw["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(layers.dense(x, lw["w_x"], lw["b_x"]).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(lw["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = i * x.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * gated


def _rglru_scan(lw: dict, x: jax.Array, h0: jax.Array | None):
    """Full-sequence RG-LRU via associative scan.  x [B,T,D]."""
    a, b = _rglru_gates(lw, x)                      # [B,T,D] f32
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def _rglru_step(lw: dict, x: jax.Array, h: jax.Array):
    """One-step RG-LRU.  x [B,1,D]; h [B,D] (f32)."""
    a, b = _rglru_gates(lw, x)
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h_new.astype(x.dtype)[:, None], h_new


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _rec_block(cfg: ModelConfig, x: jax.Array, lw: dict, shard: layers.Shard,
               cache: dict | None):
    """Griffin recurrent block.  Returns (out, new_cache)."""
    h = layers.rms_norm(x, lw["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(layers.dense(h, lw["w_gate_in"]))
    u = layers.dense(h, lw["w_rnn_in"])
    u = shard(u, "ffn_hidden")
    if cache is None:
        u, conv_state = _causal_conv(u, lw["conv_w"], lw["conv_b"])
        y, h_last = _rglru_scan(lw, u, None)
        new_cache = {"h": h_last, "conv": conv_state}
    else:
        u, conv_state = _causal_conv(u, lw["conv_w"], lw["conv_b"],
                                     cache["conv"])
        y, h_last = _rglru_step(lw, u, cache["h"])
        new_cache = {"h": h_last, "conv": conv_state}
    out = layers.dense(gate * y, lw["w_out"])
    return shard(out, "act_btd"), new_cache


def _ring_positions(pos, window: int):
    """Absolute position stored in each ring slot, given the position of the
    token being decoded (already written at slot pos % window)."""
    slot = jnp.arange(window)
    return pos - jnp.mod(pos - slot, window)


def _attn_block_ring(cfg: ModelConfig, x: jax.Array, lw: dict,
                     shard: layers.Shard, cache: dict, pos):
    """Decode-time local attention over a ring-buffer cache."""
    d, hd = cfg.d_model, cfg.head_dim_
    hq, hkv, w = cfg.num_heads, cfg.num_kv_heads, cfg.local_window
    h = layers.rms_norm(x, lw["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lw["wq"].astype(h.dtype).reshape(d, hq, hd))
    k = jnp.einsum("bsd,dhk->bshk", h, lw["wk"].astype(h.dtype).reshape(d, hkv, hd))
    v = jnp.einsum("bsd,dhk->bshk", h, lw["wv"].astype(h.dtype).reshape(d, hkv, hd))
    sin, cos = layers.rope(pos[None].astype(jnp.float32), hd, cfg.rope_theta)
    q, k = layers.apply_rope(q, sin, cos), layers.apply_rope(k, sin, cos)
    q = shard(q, "heads")

    slot = jnp.mod(pos, w)
    k_all = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                         (0, slot, 0, 0))
    v_all = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                         (0, slot, 0, 0))
    kpos = _ring_positions(pos, w)                       # [w]
    qf = q.astype(jnp.float32).reshape(q.shape[0], 1, hkv, hq // hkv, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k_all.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    mask = kpos >= 0
    s = jnp.where(mask[None, None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_all.astype(jnp.float32))
    o = o.reshape(q.shape[0], 1, hq, hd).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o,
                     lw["wo"].astype(x.dtype).reshape(hq, hd, d))
    return shard(out, "act_btd"), {"k": k_all, "v": v_all}


def _mlp(cfg: ModelConfig, x: jax.Array, lw: dict, shard: layers.Shard):
    h = layers.rms_norm(x, lw["ln2"], cfg.norm_eps)
    return layers.swiglu(h, lw["wg"].astype(h.dtype), lw["wu"].astype(h.dtype),
                         lw["wd"].astype(h.dtype), shard)


# --------------------------------------------------------------------------
# public API (mirrors models.transformer)
# --------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, batch: dict,
            shard: layers.Shard = layers.no_shard, collect_cache: bool = False,
            unembed: bool = True):
    x = tfm._embed(cfg, params, batch, shard)
    seq = x.shape[1]
    sin, cos = layers.rope(jnp.arange(seq), cfg.head_dim_, cfg.rope_theta)
    caches = []
    for kind, lw in zip(cfg.layer_kinds, params["blocks"]):
        if kind == "rec":
            def body(x, lw=lw):
                a, c = _rec_block(cfg, x, lw, shard, None)
                x = x + a
                return x + _mlp(cfg, x, lw, shard), c
        else:
            def body(x, lw=lw):
                a, kv = tfm._attn_block(cfg, x, lw, sin, cos, shard)
                x = x + a
                return x + _mlp(cfg, x, lw, shard), kv
        x, c = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)(x)
        if collect_cache:
            caches.append(c)
    if not unembed:
        x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.float32(0.0), caches if collect_cache else None
    logits = tfm._unembed(cfg, params, x, shard)
    return logits, jnp.float32(0.0), caches if collect_cache else None


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    del max_len   # the hybrid's state is O(window), not O(seq): that's the point
    w, hd, hkv = cfg.local_window, cfg.head_dim_, cfg.num_kv_heads
    dt = jnp.dtype(cfg.dtype)
    layers_cache = []
    for kind in cfg.layer_kinds:
        if kind == "rec":
            layers_cache.append({
                "h": jnp.zeros((batch_size, cfg.d_rnn_), jnp.float32),
                "conv": jnp.zeros((batch_size, cfg.conv_width - 1, cfg.d_rnn_),
                                  dt),
            })
        else:
            layers_cache.append({
                "k": jnp.zeros((batch_size, w, hkv, hd), dt),
                "v": jnp.zeros((batch_size, w, hkv, hd), dt),
            })
    return {"layers": layers_cache, "pos": jnp.zeros((), jnp.int32)}


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_len: int,
            shard: layers.Shard = layers.no_shard):
    logits, _, caches = forward(cfg, params, batch, shard, collect_cache=True)
    seq = batch["tokens"].shape[1]
    w = cfg.local_window
    out_layers = []
    for kind, c in zip(cfg.layer_kinds, caches):
        if kind == "rec":
            out_layers.append({"h": c["h"].astype(jnp.float32),
                               "conv": c["conv"]})
        else:
            k, v = c                                  # [B, S, Hkv, hd]
            b = k.shape[0]
            dt = jnp.dtype(cfg.dtype)
            if seq >= w:
                tail_k, tail_v = k[:, -w:], v[:, -w:]
                shift = seq % w
                ring_k = jnp.roll(tail_k, shift, axis=1)
                ring_v = jnp.roll(tail_v, shift, axis=1)
            else:
                pad = w - seq
                ring_k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                ring_v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            out_layers.append({"k": ring_k.astype(dt), "v": ring_v.astype(dt)})
    return logits[:, -1], {"layers": out_layers, "pos": jnp.int32(seq)}


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, shard: layers.Shard = layers.no_shard):
    pos = cache["pos"]
    x = tfm._embed(cfg, params, {"tokens": tokens}, shard)
    new_layers = []
    for kind, lw, c in zip(cfg.layer_kinds, params["blocks"],
                            cache["layers"]):
        if kind == "rec":
            a, nc = _rec_block(cfg, x, lw, shard, c)
        else:
            a, nc = _attn_block_ring(cfg, x, lw, shard, c, pos)
        x = x + a
        x = x + _mlp(cfg, x, lw, shard)
        new_layers.append(nc)
    logits = tfm._unembed(cfg, params, x, shard)
    return logits[:, -1], {"layers": new_layers, "pos": pos + 1}
