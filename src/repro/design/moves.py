"""Composable move kernels for the fleet optimizer.

A move kernel is ``move(cand, rng, space) -> Candidate | None``: propose a
neighbour of ``cand`` in ``space``, drawing all randomness from ``rng``
(the optimizer's single seeded generator — determinism and resumability
hang on kernels never touching other entropy).  ``None`` means "not
applicable here" (e.g. a parametric move on a non-parametric space, or an
infeasible parameter point) and the optimizer draws another kernel.

Kernels preserve physical feasibility by construction:

* ``swap_edges`` — double-edge swaps: remove one ``space.link_unit`` of
  capacity from links (u,v) and (x,y), add it to (u,x) and (v,y).  Every
  node's total attached capacity (its port count × line speed) is exactly
  preserved, so any wiring the kernel emits uses the same equipment.
  Swaps never create self-loops and respect ``space.forbidden_pairs`` /
  ``rewirable_mask``; parallel links are fine (capacities sum).
* ``move_servers`` — shift servers between switch classes by perturbing
  the ``servers_on_large`` design parameter and rebuilding from a fresh
  wiring seed (paper §5.1's knob).
* ``perturb_bias`` — multiplicative perturbation of the ``cross_bias``
  parameter (paper §5.2's knob), rebuilt the same way.

``MOVES`` is the registry the optimizer draws from; register custom
kernels by name to extend the search.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graphs import Topology
from repro.design.spaces import Candidate, DesignSpace

__all__ = ["swap_edges", "move_servers", "perturb_bias", "MOVES"]


def swap_edges(cand: Candidate, rng: np.random.Generator,
               space: DesignSpace, swaps: int = 4) -> Candidate | None:
    """Degree-preserving double-edge swaps on the candidate's wiring.

    Attempts up to ``swaps`` successful swaps (each moving one
    ``space.link_unit`` of capacity); gives up on a pick after a bounded
    number of rejections, so the kernel always terminates.  Returns
    ``None`` when the rewirable subgraph has fewer than two links.
    """
    topo = cand.topo
    cap = topo.cap.copy()
    unit = space.link_unit
    rewirable = space.rewirable_mask(topo)
    forbidden = space.forbidden_pairs(topo)
    swappable = space.swappable_links(topo)
    done = 0
    for _ in range(swaps * 8):
        if done >= swaps:
            break
        removable = np.triu(cap, 1) >= unit
        if swappable is not None:
            # budget-constrained spaces: only these links may be removed —
            # the mask moves with the wiring, so recompute it per swap
            removable &= np.triu(swappable, 1)
        iu, iv = np.nonzero(removable)
        ok = rewirable[iu] & rewirable[iv]
        iu, iv = iu[ok], iv[ok]
        if len(iu) < 2:
            break
        a, b = rng.choice(len(iu), size=2, replace=False)
        u, v = int(iu[a]), int(iv[a])
        x, y = int(iu[b]), int(iv[b])
        if rng.random() < 0.5:
            x, y = y, x
        # rewire (u,v)+(x,y) -> (u,x)+(v,y); reject degenerate picks
        if len({u, v, x, y}) < 4:
            continue
        if forbidden is not None and (forbidden[u, x] or forbidden[v, y]):
            continue
        for p, q, s in ((u, v, -unit), (x, y, -unit),
                        (u, x, +unit), (v, y, +unit)):
            cap[p, q] += s
            cap[q, p] += s
        done += 1
    if done == 0:
        return None
    return dataclasses.replace(
        cand, topo=Topology(cap=cap, servers=topo.servers,
                            labels=topo.labels),
        origin="swap")


def _perturb_param(cand: Candidate, rng: np.random.Generator,
                   space: DesignSpace, key: str, new_value,
                   origin: str) -> Candidate | None:
    lo, hi = space.param_bounds.get(key, (-np.inf, np.inf))
    params = {**cand.params, key: np.clip(new_value, lo, hi)}
    seed = int(rng.integers(1 << 31))
    try:
        topo = space.rebuild(params, seed)
    except ValueError:
        return None      # infeasible parameter point: kernel inapplicable
    if topo is None:
        return None
    return Candidate(topo=topo, params=params, seed=seed, origin=origin)


def move_servers(cand: Candidate, rng: np.random.Generator,
                 space: DesignSpace) -> Candidate | None:
    """Shift 1–3 servers between switch classes (perturbs the
    ``servers_on_large`` parameter; rebuilds with a fresh wiring seed)."""
    if "servers_on_large" not in cand.params:
        return None
    delta = int(rng.integers(1, 4)) * int(rng.choice((-1, 1)))
    return _perturb_param(cand, rng, space, "servers_on_large",
                          int(cand.params["servers_on_large"]) + delta,
                          origin="servers")


def perturb_bias(cand: Candidate, rng: np.random.Generator,
                 space: DesignSpace) -> Candidate | None:
    """Multiplicatively perturb the ``cross_bias`` parameter (log-normal
    step, ~±25%; rebuilds with a fresh wiring seed)."""
    if "cross_bias" not in cand.params:
        return None
    factor = float(np.exp(rng.normal(0.0, 0.25)))
    return _perturb_param(cand, rng, space, "cross_bias",
                          float(cand.params["cross_bias"]) * factor,
                          origin="bias")


# name -> kernel; the optimizer's ``moves=`` argument indexes this
MOVES = {"swap": swap_edges, "servers": move_servers, "bias": perturb_bias}
