"""Design spaces: what the fleet optimizer searches over.

The paper's second half is not a fixed recipe but a *method*: given a
heterogeneous switch pool, search the server distribution and the
interconnect for throughput (the 43% VL2 rewiring gain is one point this
search finds).  A ``DesignSpace`` makes that search space explicit:

* ``initial(seed)`` — a seeded starting ``Candidate``.  Random wirings are
  strong starting points (Jellyfish), so every concrete space seeds from
  its paper-recipe random construction — which also makes the recipe
  itself candidate 0, so the optimizer can never report a wiring worse
  than the recipe it started from.
* ``rebuild(params, seed)`` — re-run the space's constructor with perturbed
  design parameters (the *parametric* move kernels: server re-distribution
  across switch classes, cross-cluster bias).  Non-parametric spaces
  return ``None``.
* ``rewirable_mask(topo)`` / ``forbidden_pairs(topo)`` — which nodes'
  links a degree-preserving edge swap may touch, and which node pairs must
  never be directly linked (e.g. ToR–ToR in VL2).
* ``link_unit`` — capacity quantum one swap moves (1 base-speed link for
  two-class pools, one 10GbE link for VL2 fabric).
* ``param_bounds`` — clipping ranges for the parametric moves.

A ``Candidate`` pairs the concrete ``Topology`` with the design parameters
that produced it (empty for purely-rewired candidates) and its wiring-seed
lineage, so every point the search visits is reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import numpy as np

from repro.core import heterogeneous as het
from repro.core import vl2 as vl2_mod
from repro.core.graphs import Topology

__all__ = ["Candidate", "DesignSpace", "TwoClassSpace", "VL2Space"]


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of a design space: the built topology, the design
    parameters that produced it (``{}`` when the candidate exists only as
    a rewiring), and the wiring seed it was last (re)built from."""

    topo: Topology
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    seed: int = 0
    origin: str = "initial"    # move kernel that produced this candidate


class DesignSpace:
    """Base class for search spaces; concrete spaces override ``initial``
    (required) and whichever hooks their move kernels need."""

    link_unit: float = 1.0     # capacity one edge swap moves between pairs

    # clipping bounds per parametric-move key ({} = no parametric moves)
    param_bounds: Mapping[str, tuple[float, float]] = {}

    def initial(self, seed: int) -> Candidate:
        """A seeded starting candidate (the space's paper recipe)."""
        raise NotImplementedError

    def rebuild(self, params: Mapping[str, Any],
                seed: int) -> Topology | None:
        """Re-run the constructor with new ``params``; ``None`` when the
        space has no parametric form.  May raise ``ValueError`` for an
        infeasible parameter point (the move kernel treats that as
        'inapplicable' and the optimizer draws another move)."""
        return None

    def rewirable_mask(self, topo: Topology) -> np.ndarray:
        """[N] bool: nodes whose incident links edge swaps may rewire."""
        return np.ones(topo.n, dtype=bool)

    def forbidden_pairs(self, topo: Topology) -> np.ndarray | None:
        """[N, N] bool (True = this pair must never be directly linked),
        or None when any switch pair may be wired."""
        return None

    def swappable_links(self, topo: Topology) -> np.ndarray | None:
        """[N, N] bool (True = an edge swap may REMOVE a ``link_unit`` from
        this pair), or None when every present link is fair game.  Spaces
        with a recabling budget (``repro.lifecycle.ExpansionSpace``)
        restrict removal to links that are already deviations from a base
        wiring — a swap then moves changed links around without ever
        disturbing another original link, so the budget can only shrink."""
        return None


class TwoClassSpace(DesignSpace):
    """The §5 two-class pool: search server placement, cross-cluster bias,
    and the wiring itself.  Parametric over ``servers_on_large`` (server
    re-distribution across switch classes) and ``cross_bias``."""

    def __init__(self, spec: het.TwoClassSpec):
        self.spec = spec
        self.param_bounds = {
            "servers_on_large": (0, spec.num_servers),
            "cross_bias": (0.05, 4.0),
        }

    def initial(self, seed: int) -> Candidate:
        params = {"servers_on_large": self.spec.proportional_large_servers,
                  "cross_bias": 1.0}
        return Candidate(topo=self.rebuild(params, seed), params=params,
                         seed=seed)

    def rebuild(self, params, seed: int) -> Topology:
        return het.build_two_class(self.spec,
                                   int(params["servers_on_large"]),
                                   float(params["cross_bias"]), seed)


class VL2Space(DesignSpace):
    """The §7 VL2 equipment pool at a fixed ToR count: candidates are
    degree-preserving rewirings of the paper's proportional random rewiring
    (``vl2.rewired_vl2_topology`` is candidate 0).  All links are 10GbE, so
    one swap moves a whole fabric link; ToR–ToR links are forbidden (a ToR's
    two uplinks must reach the switching fabric)."""

    link_unit = vl2_mod.FABRIC

    def __init__(self, spec: vl2_mod.VL2Spec, n_tor: int):
        self.spec = spec
        self.n_tor = n_tor

    def initial(self, seed: int) -> Candidate:
        topo = vl2_mod.rewired_vl2_topology(self.spec, self.n_tor, seed)
        return Candidate(topo=topo, params={}, seed=seed)

    def forbidden_pairs(self, topo: Topology) -> np.ndarray:
        tor = topo.labels == 0
        return tor[:, None] & tor[None, :]
