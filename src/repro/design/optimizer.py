"""Batched stochastic topology optimizer: fleet search through one
``BatchPlan.execute`` per round.

"Measuring ... Throughput of Network Topologies" (Jyothi et al.) makes the
cost of topology comparison explicit: every candidate needs a
max-concurrent-flow solve over several traffic samples.  That is exactly
the workload the ``BatchPlan`` execution core makes cheap, so the search
loop is built around it:

1. **Seed a fleet** of candidates from the space's paper recipe
   (``space.initial``; candidate 0 is the recipe itself) and evaluate all
   of them — ``fleet × runs`` instances — in ONE ``BatchPlan.execute``.
2. **Each round**, propose ``fleet`` neighbours of the elite set via the
   move kernels (``repro.design.moves``), and evaluate the whole proposal
   fleet in ONE ``BatchPlan.execute``.  Same-size candidates land in one
   bucket/chunk, so every round after the first re-executes the SAME
   compiled program (``BatchPlan.refill`` reuses the round-one plan
   structure — identical compile keys by construction).
3. **Rank cheaply, certify finally.**  Rounds rank candidates by the
   engine's fast certified bound (dual upper bound by default) aggregated
   pessimistically (min) across the traffic samples.  After the last
   round the elite set PLUS the recipe reference get one certification
   pass (``solver="primal"``: certified lower bound + the free dual upper
   bound), and the reported ``best`` maximises the certified lower bound
   — so the optimizer's claim is a proof, and it can never report a
   wiring certified worse than the recipe it started from.
4. **Seeded and resumable.** All randomness flows through one
   ``numpy.random.Generator``; ``DesignResult.state`` carries its exact
   bit-generator state plus the elite set, and ``optimize(...,
   state=...)`` continues the search as if it had never stopped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.core import traffic as traffic_mod
from repro.core.engine import DualEngine, _PlannedEngine, as_engine
from repro.core.plan import BatchPlan
from repro.design.moves import MOVES
from repro.design.spaces import Candidate, DesignSpace

__all__ = ["Evaluated", "DesignState", "DesignResult", "optimize"]


@dataclasses.dataclass(frozen=True)
class Evaluated:
    """A candidate with its fleet-evaluation scores.

    ``score`` is the ranking value used during search rounds — the
    engine's per-instance certified bound, aggregated by ``agg`` (min by
    default) over the ``runs`` traffic samples.  ``lb``/``ub`` are filled
    by the final certification pass: the certified lower bound (an
    explicit feasible flow exists at this rate for EVERY sample) and the
    matching dual upper bound; ``None`` before certification.
    """

    cand: Candidate
    score: float
    values: tuple[float, ...]      # per-traffic-sample ranking values
    lb: float | None = None        # certified min-over-samples lower bound
    ub: float | None = None        # min-over-samples dual upper bound


@dataclasses.dataclass
class DesignState:
    """Everything needed to resume a search exactly where it stopped:
    the RNG's bit-generator state, the current elite set, the recipe
    reference, and the bookkeeping counters.  ``optimize(space, ...,
    state=...)`` continues seamlessly — ``optimize(rounds=a)`` then
    ``optimize(rounds=b, state=...)`` visits the same candidates as one
    ``optimize(rounds=a+b)`` call."""

    rng_state: dict
    elites: list[Evaluated]        # SEARCH (score) order, not lb order —
    #                                resume must see the same parent
    #                                rotation as an uninterrupted run
    reference: Evaluated
    rounds_done: int
    executes: int
    compile_keys: tuple[tuple[int, int], ...]
    eval_seeds: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class DesignResult:
    """Outcome of ``optimize``: the certified-best candidate, the elite
    set, the recipe reference it is guaranteed to match-or-beat, the
    per-round trajectory, plan/compile stats, and the resumable state."""

    best: Evaluated                # argmax certified lb over elites+reference
    elites: list[Evaluated]        # certified, sorted by lb (desc)
    reference: Evaluated           # candidate 0 = the space's paper recipe
    history: list[dict]            # per-round {round, best_score, mean_score}
    stats: dict                    # executes/compile_keys/instances/plan
    state: DesignState


def _aggregate(vals: np.ndarray, agg: str) -> float:
    if agg == "min":
        return float(vals.min())
    if agg == "mean":
        return float(vals.mean())
    raise ValueError(f"unknown agg {agg!r}; expected 'min' or 'mean'")


def optimize(space: DesignSpace,
             demand_fn: Callable[[Any, int], np.ndarray] | None = None,
             *,
             engine: str | _PlannedEngine | None = None,
             moves: Sequence[str] = ("swap", "servers", "bias"),
             rounds: int = 4,
             fleet: int = 12,
             elite: int = 4,
             runs: int = 2,
             seed: int = 0,
             agg: str = "min",
             robust: bool | dict = False,
             state: DesignState | None = None) -> DesignResult:
    """Search ``space`` for a high-throughput wiring.

    ``demand_fn(topo, seed) -> dem[N, N]`` draws one traffic sample
    (default: a random server permutation); every candidate is scored on
    the same ``runs`` fixed seeds so ranking is apples-to-apples across
    rounds.  ``engine`` must be a planning engine (``"dual"`` /
    ``"dual-pallas"`` / ``"primal"`` / ``"certified"`` or a
    ``_PlannedEngine`` instance — the search NEEDS ``BatchPlan``; default:
    a ``DualEngine(iters=250, tol=1e-3)`` tuned for cheap ranking).
    ``moves`` names kernels from ``repro.design.moves.MOVES``.  Kernels
    inapplicable to ``space`` are skipped automatically; if no listed
    kernel applies the proposal falls back to a fresh seeded initial
    candidate (pure random restart).

    Execution cost is exactly ``1 + rounds`` search ``BatchPlan.execute``
    calls of ``fleet × runs`` instances each (round one builds the plan,
    later rounds ``refill`` it — zero recompiles) plus ONE final
    certification execute over ``(elite + 1) × runs`` instances.

    ``robust`` re-bases the FINAL ranking on worst-case traffic: after
    the sampled-traffic search rounds, each unique elite (plus the
    reference) gets an adversarial worst-TM search over its hose polytope
    (``repro.core.adversarial.find_worst_tm``), and the reported
    ``lb``/``ub`` become that worst TM's certified bracket — ``best``
    maximises the worst-case lower bound, which is the ranking Jyothi et
    al. show can FLIP relative to sampled traffic.  Pass a dict to
    forward search knobs (``rounds`` / ``candidates`` / ``iters`` / ...);
    ``True`` uses a small default budget.  Search rounds still rank by
    cheap sampled bounds (the execute-count contract above is unchanged);
    ``stats["robust"]`` records the extra adversarial executes.
    """
    if fleet < 1 or rounds < 0 or runs < 1 or elite < 1:
        raise ValueError("need fleet >= 1, rounds >= 0, runs >= 1, "
                         "elite >= 1")
    unknown = [m for m in moves if m not in MOVES]
    if unknown:
        raise ValueError(f"unknown move kernel(s) {unknown}; "
                         f"known: {sorted(MOVES)}")
    if demand_fn is None:
        demand_fn = lambda topo, s: traffic_mod.make(  # noqa: E731
            "permutation", topo.servers, s)
    eng = DualEngine(iters=250, tol=1e-3) if engine is None \
        else as_engine(engine)
    if not isinstance(eng, _PlannedEngine):
        raise ValueError(
            f"engine {getattr(eng, 'name', eng)!r} does not execute through "
            "a BatchPlan; the designer needs one of dual/dual-pallas/"
            "primal/certified (exact LP ranking would solve the fleet "
            "sequentially)")

    executes = 0
    all_keys: set[tuple[int, int]] = set()
    search_plan: BatchPlan | None = None   # refilled round to round

    def evaluate(cands: list[Candidate], eval_seeds, *,
                 solver: str | None = None) -> list[list]:
        """ONE BatchPlan.execute over the cands × eval_seeds fleet;
        returns per-candidate lists of InstanceSolve (sample-major)."""
        nonlocal executes, search_plan
        topos = [c.topo for c in cands for _ in eval_seeds]
        dems = [demand_fn(c.topo, s) for c in cands for s in eval_seeds]
        plan = None
        if solver is None and search_plan is not None:
            try:
                plan = search_plan.refill(topos, dems)
            except ValueError:
                plan = None            # fleet shape drifted: re-plan
        if plan is None:
            plan = eng.plan(topos, dems)
        if solver is None:
            search_plan = plan
        executes += 1
        all_keys.update(plan.stats.compile_keys)
        solved = plan.execute(solver=solver or eng.solver,
                              **eng._solver_kw())
        k = len(eval_seeds)
        return [solved[i * k:(i + 1) * k] for i in range(len(cands))]

    def score_fleet(cands: list[Candidate], eval_seeds) -> list[Evaluated]:
        out = []
        for cand, solves in zip(cands, evaluate(cands, eval_seeds)):
            vals = np.asarray([s.value for s in solves])
            out.append(Evaluated(cand=cand, score=_aggregate(vals, agg),
                                 values=tuple(float(v) for v in vals)))
        return out

    history: list[dict] = []
    rng = np.random.default_rng(seed)
    if state is not None:
        rng.bit_generator.state = state.rng_state
        elites = list(state.elites)
        reference = state.reference
        eval_seeds = state.eval_seeds
        round0 = state.rounds_done
        executes = state.executes
        all_keys.update(state.compile_keys)
    else:
        # fixed per-search traffic sample seeds: every candidate in every
        # round is scored on the same demands
        eval_seeds = tuple(100003 * (seed + 1) + j for j in range(runs))
        reference_cand = space.initial(seed)
        init = [reference_cand] + \
            [space.initial(int(rng.integers(1 << 31)))
             for _ in range(fleet - 1)]
        scored = score_fleet(init, eval_seeds)
        reference = scored[0]
        elites = sorted(scored, key=lambda e: -e.score)[:elite]
        round0 = 0
        history.append({"round": 0, "best_score": elites[0].score,
                        "mean_score":
                            float(np.mean([e.score for e in scored]))})

    applicable = list(moves)
    for r in range(round0, round0 + rounds):
        proposals: list[Candidate] = []
        for i in range(fleet):
            parent = elites[i % len(elites)].cand
            new = None
            for _ in range(8):
                name = applicable[int(rng.integers(len(applicable)))]
                new = MOVES[name](parent, rng, space)
                if new is not None:
                    break
            if new is None:     # no kernel applies: pure random restart
                new = space.initial(int(rng.integers(1 << 31)))
            proposals.append(new)
        scored = score_fleet(proposals, eval_seeds)
        merged = sorted(elites + scored, key=lambda e: -e.score)
        elites = merged[:elite]
        history.append({"round": r + 1, "best_score": elites[0].score,
                        "mean_score":
                            float(np.mean([e.score for e in scored]))})

    # final certification: the in-loop elites plus the recipe reference,
    # primal solver (certified lower bound; the dual upper bound rides
    # along in meta).  The reference is certified ONCE even when it also
    # survived as an elite (it is candidate 0, so with small fleets it
    # often does) — no duplicate lanes, and identity is preserved so the
    # resumable state keeps elite membership exactly as the search left it.
    unique = list(elites)
    if not any(e is reference for e in unique):
        unique.append(reference)
    certified: dict[int, Evaluated] = {}
    for ev, solves in zip(unique, evaluate([e.cand for e in unique],
                                           eval_seeds, solver="primal")):
        lbs = np.asarray([s.value for s in solves])
        ubs = np.asarray([s.meta["ub"] for s in solves])
        certified[id(ev)] = dataclasses.replace(
            ev, lb=float(lbs.min()), ub=float(ubs.min()))
    robust_stats = None
    if robust:
        # worst-case re-ranking: each unique candidate's lb/ub become the
        # certified bracket of its adversarially-found worst TM (its own
        # BatchPlans — the sampled-traffic execute contract is untouched)
        from repro.core.adversarial import find_worst_tm
        adv_kw = dict(robust) if isinstance(robust, dict) else {}
        adv_kw.setdefault("rounds", 2)
        adv_kw.setdefault("candidates", 4)
        adv_kw.setdefault("iters", eng.iters)
        adv_executes = 0
        for ev in unique:
            res = find_worst_tm(ev.cand.topo, seed=seed, **adv_kw)
            adv_executes += res.stats["executes"]
            certified[id(ev)] = dataclasses.replace(
                certified[id(ev)], lb=res.lb, ub=res.ub)
        robust_stats = {**{k: adv_kw[k]
                           for k in ("rounds", "candidates", "iters")},
                        "executes": adv_executes}
    # state keeps SEARCH (score) order and membership — resuming must pair
    # the rng stream with the same parents as an uninterrupted run; the
    # result's elite list is re-sorted by what the certification proved
    state_elites = [certified[id(e)] for e in elites]
    cert_reference = certified[id(reference)]
    cert_elites = sorted(state_elites, key=lambda e: -e.lb)
    best = max(certified.values(), key=lambda e: e.lb)

    rounds_done = round0 + rounds
    final_state = DesignState(
        rng_state=rng.bit_generator.state, elites=state_elites,
        reference=cert_reference, rounds_done=rounds_done,
        executes=executes, compile_keys=tuple(sorted(all_keys)),
        eval_seeds=tuple(eval_seeds))
    stats = {
        "rounds": rounds_done, "fleet": fleet, "elite": elite,
        "runs": runs, "executes": executes,
        # the init eval + exactly ONE execute per search round; the rest
        # are certification passes (one per optimize() call)
        "search_executes": 1 + rounds_done,
        "certify_executes": executes - (1 + rounds_done),
        "instances_per_round": fleet * runs,
        "compile_keys": tuple(sorted(all_keys)),
        "engine": getattr(eng, "name", "dual"), "agg": agg,
        "robust": robust_stats,
        "last_plan": (search_plan.stats.as_dict()
                      if search_plan is not None else None),
    }
    return DesignResult(best=best, elites=cert_elites,
                        reference=cert_reference, history=history,
                        stats=stats, state=final_state)
