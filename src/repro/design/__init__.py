"""Batched topology design: fleet search over wirings through one BatchPlan.

The paper's design method — start from random wirings, search server
placement and interconnect for throughput — as a seeded, resumable
stochastic optimizer whose every search round is ONE
``BatchPlan.execute`` over the whole candidate fleet::

    from repro.design import TwoClassSpace, optimize
    from repro.core import heterogeneous as het

    spec = het.TwoClassSpec(n_large=10, k_large=18, n_small=20, k_small=6,
                            num_servers=90)
    result = optimize(TwoClassSpace(spec), rounds=4, fleet=12, seed=0)
    print(result.best.lb, "vs recipe", result.reference.lb)

Modules: ``spaces`` (DesignSpace protocol + the two-class and VL2 pools),
``moves`` (composable move kernels: degree-preserving edge swaps, server
re-distribution, cross-bias perturbation), ``optimizer`` (the fleet loop,
elite selection, final primal certification).  Drivers:
``repro.core.vl2.designed_vl2_topology`` and
``repro.core.heterogeneous.optimize_spec`` wrap this package;
``benchmarks/design_bench.py`` tracks best-found vs paper-recipe
throughput across PRs.
"""
from repro.design.moves import MOVES, move_servers, perturb_bias, swap_edges  # noqa: F401,E501
from repro.design.optimizer import (  # noqa: F401
    DesignResult, DesignState, Evaluated, optimize,
)
from repro.design.spaces import (  # noqa: F401
    Candidate, DesignSpace, TwoClassSpace, VL2Space,
)
