"""Incremental expansion: grow a live network with minimal recabling.

Jellyfish's observation (arXiv 1110.1687) is that random-graph fabrics
expand incrementally: to add a switch, break a few existing links (u, v)
and wire (u, s), (v, s) through the new switch s.  Every broken link
survives as the two-hop path u–s–v at full capacity, so every flow the
old network carried still embeds in the new one — throughput can only go
up.  This module turns that into a certified planner:

* ``attach_new_switches`` — the Jellyfish attach, budgeted: at most
  ``max_breaks`` existing links are broken (the recabling cost of the
  step); leftover new-switch ports stay spare rather than blow the
  budget.
* ``ExpansionSpace`` — a ``DesignSpace`` over the attached wiring whose
  ``swappable_links`` hook restricts edge swaps to links ADDED relative
  to the pre-expansion base.  A swap can move added links around or put a
  broken base link back, but can never remove another base link — so the
  recabled-link count is non-increasing under search and
  ``max_recabled_links`` is an invariant, not a hope.
* ``plan_expansion`` — the growth loop: per step, attach the step's new
  switches, then run ``design.optimize`` (swap moves only, the attach
  wiring as the un-beatable reference) to spend the recabling budget
  where it buys throughput.  Each step reports a certified (lb, ub)
  bracket; the certified lb is monotone non-decreasing BY CONSTRUCTION:
  the attach preserves the previous step's flows, so the previous
  certified lb is inherited as a valid bound for the attached wiring,
  and a rewired candidate replaces it only when its own measured
  certificate is higher.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.engine import _PlannedEngine
from repro.core.graphs import Topology
from repro.design.optimizer import optimize
from repro.design.spaces import Candidate, DesignSpace

__all__ = ["Attachment", "attach_new_switches", "recabled_links",
           "ExpansionSpace", "ExpansionStep", "ExpansionResult",
           "plan_expansion"]


@dataclasses.dataclass(frozen=True)
class Attachment:
    """One Jellyfish attach: the grown topology (old nodes first, new
    switches appended), how many existing links were broken to wire it
    (== recabled base links), and how many new ports stayed spare."""

    topo: Topology
    broken_links: int
    spare_ports: int


def attach_new_switches(topo: Topology, ports: Sequence[int], *,
                        link_unit: float = 1.0, seed: int = 0,
                        labels: Sequence[int] | None = None,
                        max_breaks: int | None = None,
                        forbidden: np.ndarray | None = None) -> Attachment:
    """Attach new switches Jellyfish-style: for each new switch with ``p``
    ports, break up to ``p // 2`` random existing links (u, v) — both
    endpoints among the ORIGINAL nodes — and wire (u, s), (v, s) at
    ``link_unit`` capacity each.

    Old flows are preserved (each broken link becomes a two-hop path of
    the same capacity through s), so θ* never drops.  ``max_breaks`` caps
    the total recabling; once spent, remaining new ports stay spare.
    ``labels`` assigns label values to the new switches (required iff the
    base topology is labeled); ``forbidden[n, n]`` (post-growth size)
    vetoes breaking a link whose re-wiring would create a forbidden pair.
    New switches host no servers (fabric growth).
    """
    ports = [int(p) for p in ports]
    if any(p < 0 for p in ports):
        raise ValueError(f"ports must be non-negative, got {ports}")
    n0, k = topo.n, len(ports)
    n = n0 + k
    cap = np.zeros((n, n))
    cap[:n0, :n0] = topo.cap
    servers = np.concatenate([topo.servers, np.zeros(k, np.int64)])
    if (topo.labels is None) != (labels is None):
        raise ValueError("labels must be given exactly when the base "
                         "topology is labeled")
    lab = None if topo.labels is None else np.concatenate(
        [topo.labels, np.asarray(list(labels), np.int64)])
    if forbidden is not None and forbidden.shape != (n, n):
        raise ValueError(f"forbidden must be ({n}, {n}) (post-growth), "
                         f"got {forbidden.shape}")
    rng = np.random.default_rng(seed)
    budget = np.inf if max_breaks is None else int(max_breaks)
    breaks = 0
    spare = 0
    for j, p in enumerate(ports):
        s = n0 + j
        wired = 0
        for _ in range(p // 2):
            if breaks >= budget:
                break
            iu, iv = np.nonzero(np.triu(cap[:n0, :n0], 1) >= link_unit)
            if forbidden is not None and len(iu):
                ok = ~(forbidden[iu, s] | forbidden[iv, s])
                iu, iv = iu[ok], iv[ok]
            if not len(iu):
                break
            pick = int(rng.integers(len(iu)))
            u, v = int(iu[pick]), int(iv[pick])
            for a, b, d in ((u, v, -link_unit), (u, s, +link_unit),
                            (v, s, +link_unit)):
                cap[a, b] += d
                cap[b, a] += d
            breaks += 1
            wired += 1
        spare += p - 2 * wired
    out = Topology(cap=cap, servers=servers, labels=lab)
    out.validate()
    return Attachment(topo=out, broken_links=breaks, spare_ports=spare)


def recabled_links(base_cap: np.ndarray, cap: np.ndarray,
                   link_unit: float = 1.0) -> int:
    """How many base links (in ``link_unit`` quanta) are no longer present
    in ``cap`` — the physical recabling cost of going from the base wiring
    to ``cap``.  ``cap`` may be larger than ``base_cap`` (grown network);
    capacity ADDED anywhere is free, only removed base capacity counts."""
    n0 = base_cap.shape[0]
    removed = np.maximum(base_cap - cap[:n0, :n0], 0.0)
    return int(round(np.triu(removed, 1).sum() / link_unit))


class ExpansionSpace(DesignSpace):
    """Search space of one expansion step: rewirings of the attached
    topology whose deviation from the PRE-EXPANSION base wiring never
    grows.  ``swappable_links`` allows removal only where capacity exceeds
    the base (links the attach or an earlier swap added), so base links
    never disappear beyond those the attach already broke — the recabling
    budget is enforced structurally, not by rejection sampling.

    Geometry note: a double-edge swap needs two removable links with four
    DISTINCT endpoints, so a step that attaches a single switch (every
    added link incident to it) admits no swap at all and keeps the attach
    wiring — steps adding two or more switches give the search room."""

    def __init__(self, start: Topology, base_cap: np.ndarray, *,
                 link_unit: float = 1.0,
                 forbidden: np.ndarray | None = None,
                 rewirable: np.ndarray | None = None):
        self.start = start
        n = start.n
        padded = np.zeros((n, n))
        n0 = base_cap.shape[0]
        padded[:n0, :n0] = base_cap
        self.base_cap = padded
        self.link_unit = float(link_unit)
        self._forbidden = forbidden
        self._rewirable = rewirable

    def initial(self, seed: int) -> Candidate:
        return Candidate(topo=self.start, params={}, seed=seed)

    def rewirable_mask(self, topo: Topology) -> np.ndarray:
        if self._rewirable is not None:
            return self._rewirable
        return np.ones(topo.n, dtype=bool)

    def forbidden_pairs(self, topo: Topology) -> np.ndarray | None:
        return self._forbidden

    def swappable_links(self, topo: Topology) -> np.ndarray:
        return (topo.cap - self.base_cap) >= self.link_unit * (1 - 1e-9)


@dataclasses.dataclass(frozen=True)
class ExpansionStep:
    """One point of the growth trajectory.  ``lb`` is a certified lower
    bound on this wiring's throughput under the fixed demand: measured by
    the primal certificate, or inherited from the previous step when the
    step kept the attach wiring (``lb_source``) — inheritance is sound
    because the attach embeds every previous flow."""

    topo: Topology
    new_switches: int
    new_ports: int
    spare_ports: int
    recabled: int           # base links moved this step (<= the budget)
    lb: float
    ub: float
    lb_source: str          # "measured" | "inherited"
    chose: str              # "start" | "attached" | "rewired"


@dataclasses.dataclass(frozen=True)
class ExpansionResult:
    """The certified growth trajectory (steps[0] is the starting network)
    plus search accounting aggregated over the per-step optimizer runs."""

    steps: list[ExpansionStep]
    stats: dict


def plan_expansion(topo: Topology, growth: Sequence[Sequence[int]], *,
                   max_recabled_links: int = 4,
                   engine: _PlannedEngine | None = None,
                   demand_fn: Callable | None = None,
                   new_labels: Sequence[int] | None = None,
                   forbidden_fn: Callable[[Topology], np.ndarray] | None
                   = None,
                   link_unit: float = 1.0,
                   rounds: int = 2, fleet: int = 8, elite: int = 3,
                   runs: int = 2, seed: int = 0) -> ExpansionResult:
    """Plan a multi-step expansion of ``topo`` under a recabling budget.

    ``growth`` is one port-count list per step (e.g. ``[[4], [4], [4]]``
    adds one 4-port switch per step for three steps).  Each step attaches
    the new switches (breaking at most ``max_recabled_links`` existing
    links), then spends ``rounds`` fleet-search rounds of swap moves
    inside an ``ExpansionSpace`` — so the final wiring of every step is
    guaranteed within budget.  ``demand_fn(topo, sample_seed)`` fixes the
    load (default: the optimizer's random server permutation); new
    switches host no servers, so the SAME demand spans all steps and
    certified bounds are comparable along the trajectory.

    The reported per-step ``lb`` is monotone non-decreasing by
    construction: the attach preserves the previous wiring's flows, so
    ``max(previous lb, attached wiring's measured lb)`` certifies the
    attached wiring; a rewired candidate is adopted only when its own
    measured certificate beats that.  ``new_labels`` / ``forbidden_fn``
    carry class structure through growth (e.g. VL2: label new cores 2,
    keep ToR–ToR pairs forbidden).
    """
    if max_recabled_links < 0:
        raise ValueError("max_recabled_links must be >= 0")
    steps: list[ExpansionStep] = []
    executes = 0
    keys: set[tuple[int, int]] = set()

    def certify(space: ExpansionSpace, *, srounds: int, sfleet: int,
                selite: int, step_seed: int):
        nonlocal executes
        res = optimize(space, demand_fn, engine=engine, moves=("swap",),
                       rounds=srounds, fleet=sfleet, elite=selite,
                       runs=runs, seed=step_seed, agg="min")
        executes += res.stats["executes"]
        keys.update(res.stats["compile_keys"])
        return res

    # step 0: certify the starting network (no growth, no recabling).
    # seed is shared by every step's optimize() call ON PURPOSE: the
    # optimizer derives its fixed traffic-sample seeds from it, so all
    # steps are certified against the same demand draws.
    space0 = ExpansionSpace(topo, topo.cap, link_unit=link_unit,
                            forbidden=(forbidden_fn(topo)
                                       if forbidden_fn else None))
    res0 = certify(space0, srounds=0, sfleet=1, selite=1, step_seed=seed)
    prev_lb = res0.best.lb
    steps.append(ExpansionStep(
        topo=topo, new_switches=0, new_ports=0, spare_ports=0, recabled=0,
        lb=prev_lb, ub=res0.best.ub, lb_source="measured", chose="start"))

    current = topo
    for si, ports in enumerate(growth):
        att_seed = int(np.random.default_rng((seed, 13, si))
                       .integers(1 << 31))
        if current.labels is not None:
            if new_labels is None:
                raise ValueError("labeled topology needs new_labels")
            lab_seq = list(new_labels)
            step_labels = [lab_seq[j % len(lab_seq)]
                           for j in range(len(ports))]
        else:
            step_labels = None
        # probe the forbidden structure on the grown node set (attach
        # enforces the same mask internally while wiring)
        forb = None
        if forbidden_fn is not None:
            probe = Topology(
                cap=np.pad(current.cap, (0, len(ports))),
                servers=np.concatenate(
                    [current.servers, np.zeros(len(ports), np.int64)]),
                labels=(None if current.labels is None else np.concatenate(
                    [current.labels,
                     np.asarray(step_labels, np.int64)])))
            forb = forbidden_fn(probe)
        att = attach_new_switches(current, ports, link_unit=link_unit,
                                  seed=att_seed, labels=step_labels,
                                  max_breaks=max_recabled_links,
                                  forbidden=forb)
        space = ExpansionSpace(att.topo, current.cap, link_unit=link_unit,
                               forbidden=forb)
        res = certify(space, srounds=rounds, sfleet=fleet, selite=elite,
                      step_seed=seed)
        # the attach wiring is optimize()'s reference (candidate 0): its
        # measured lb, improved to the inherited bound from the previous
        # step (valid: the attach embeds every previous flow)
        attached_lb = max(res.reference.lb, prev_lb)
        best = res.best
        if best.lb > attached_lb:
            chosen, lb, src, chose = best.cand.topo, best.lb, \
                "measured", "rewired"
            ub = best.ub
        else:
            chosen, lb, chose = att.topo, attached_lb, "attached"
            src = ("measured" if res.reference.lb >= prev_lb
                   else "inherited")
            ub = res.reference.ub
        recabled = recabled_links(current.cap, chosen.cap, link_unit)
        if recabled > max_recabled_links:       # structural invariant
            raise AssertionError(
                f"step {si}: recabled {recabled} exceeds budget "
                f"{max_recabled_links} — ExpansionSpace leaked a removal")
        steps.append(ExpansionStep(
            topo=chosen, new_switches=len(ports),
            new_ports=int(sum(ports)), spare_ports=att.spare_ports,
            recabled=recabled, lb=lb, ub=ub, lb_source=src, chose=chose))
        prev_lb = lb
        current = chosen

    stats = {
        "steps": len(growth),
        "max_recabled_links": max_recabled_links,
        "executes": executes,
        "compile_keys": tuple(sorted(keys)),
        "rounds": rounds, "fleet": fleet, "elite": elite, "runs": runs,
        "final_nodes": current.n,
        "lb_trajectory": tuple(s.lb for s in steps),
    }
    return ExpansionResult(steps=steps, stats=stats)
