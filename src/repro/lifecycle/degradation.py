"""Certified throughput-degradation surfaces through one plan per round.

For each topology family and failure kind, sweep failure fraction ×
trials and report what survives: certified (lb, ub) throughput brackets
with quantile bands, plus ``reachable_fraction`` — the share of the
demand still routable after the failure (graceful degradation, never a
crash: unroutable demand is dropped by ``mcf.drop_disconnected`` before
any solver sees it, and a fully-unroutable trial scores a certified
lb = ub = 0 without running a solver at all).

The whole surface is planner-shaped, like ``design.optimize``'s rounds:
every scenario keeps its base node count (``lifecycle.failures``), so the
(families × fractions × trials) pile of one failure kind is shape-
identical to the next kind's pile — the first kind builds ONE
``BatchPlan``, every later kind ``refill``s it and re-executes the same
compiled programs.  A surface over three kinds costs three
``BatchPlan.execute`` calls and a single-digit set of XLA compile keys,
no matter how many trials ride in each.

Fully-dead trials still occupy their lane (a stand-in solve of the base
topology keeps the pile refill-compatible); their results are overridden
to the certified zero bracket afterwards.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core import mcf
from repro.core import traffic as traffic_mod
from repro.core.engine import CertifiedEngine, _PlannedEngine
from repro.core.graphs import Topology
from repro.lifecycle.failures import FAIL_KINDS, scenario_fleet

__all__ = ["DegradationPoint", "DegradationResult", "degradation_surface"]


@dataclasses.dataclass(frozen=True)
class DegradationPoint:
    """One (family, failure kind, failure fraction) cell of the surface,
    aggregated over the trials: certified lower-bound quantile band
    (q10 / median / q90), mean dual upper bound, worst relative bracket
    gap, and the mean routable-demand share (1.0 = nothing unreachable,
    0.0 = every trial fully disconnected)."""

    family: str
    kind: str
    fraction: float
    trials: int
    lb_q10: float
    lb_med: float
    lb_q90: float
    ub_mean: float
    gap_max: float
    reachable_mean: float
    dead_trials: int        # trials whose demand was entirely unroutable


@dataclasses.dataclass(frozen=True)
class DegradationResult:
    """The full surface plus its execution accounting (one execute per
    failure kind, shared compile keys across kinds via ``refill``)."""

    points: list[DegradationPoint]
    stats: dict


def degradation_surface(families: Mapping[str, Topology], *,
                        kinds: Sequence[str] = tuple(FAIL_KINDS),
                        fractions: Sequence[float] = (0.05, 0.1, 0.2, 0.4),
                        trials: int = 20,
                        engine: _PlannedEngine | None = None,
                        traffic: str = "permutation",
                        traffic_kw: Mapping | None = None,
                        seed: int = 0) -> DegradationResult:
    """Certified throughput-vs-failure-fraction curves for every family.

    ``families`` maps a display name to its base ``Topology``.  Demand is
    drawn ONCE per (family, trial) on the ORIGINAL topology (seeded from
    ``seed``), then shared by every fraction and failure kind of that
    trial — degradation is measured against the load the intact network
    was serving, and curves are paired across kinds.  ``engine`` must be
    a planning engine running the primal solver (``CertifiedEngine`` by
    default, ``PrimalEngine`` also works): the curves are certified
    brackets, so a dual-only engine is rejected.

    Execution cost: exactly ``len(kinds)`` ``BatchPlan.execute`` calls of
    ``len(families) * len(fractions) * trials`` lanes each; kinds after
    the first ``refill`` the first kind's plan (identical pile shapes by
    construction), keeping the compile-key set shared.
    """
    eng = CertifiedEngine(iters=300, tol=1e-3) if engine is None else engine
    if not isinstance(eng, _PlannedEngine) or eng.solver != "primal":
        raise ValueError(
            "degradation_surface reports certified brackets: engine must "
            "be a planning engine running the primal solver "
            "(certified/primal), got "
            f"{getattr(eng, 'name', eng)!r}")
    if trials < 1:
        raise ValueError(f"need trials >= 1, got {trials}")
    fam_items = list(families.items())
    if not fam_items:
        raise ValueError("need at least one family")
    unknown = [k for k in kinds if k not in FAIL_KINDS]
    if unknown:
        raise ValueError(f"unknown failure kind(s) {unknown}; "
                         f"known: {list(FAIL_KINDS)}")

    # demand per (family, trial), drawn once on the intact topology
    base_dems: dict[tuple[int, int], np.ndarray] = {}
    for fam_i, (_, base) in enumerate(fam_items):
        for t in range(trials):
            ds = int(np.random.default_rng(
                (seed, 7, fam_i, t)).integers(1 << 31))
            base_dems[fam_i, t] = traffic_mod.make(
                traffic, base.servers, ds, **(traffic_kw or {}))

    plan = None
    executes = 0
    refills = 0
    keys: set[tuple[int, int]] = set()
    points: list[DegradationPoint] = []
    for kind in kinds:
        pile_topos, pile_dems = [], []
        lane_reach: list[float] = []
        lane_dead: list[bool] = []
        for fam_i, (_, base) in enumerate(fam_items):
            for sc in scenario_fleet(base, kind, fractions, trials,
                                     seed=seed):
                dem = base_dems[fam_i, sc.trial]
                kept, dropped = mcf.drop_disconnected(sc.topo.cap, dem)
                dead = dropped >= 1.0
                if dead:
                    # stand-in lane: keeps this kind's pile shape-identical
                    # to the others so refill applies; result overridden to
                    # the certified zero bracket below
                    pile_topos.append(base)
                    pile_dems.append(dem)
                else:
                    pile_topos.append(sc.topo)
                    pile_dems.append(kept)
                lane_reach.append(1.0 - dropped)
                lane_dead.append(dead)
        if plan is None:
            plan = eng.plan(pile_topos, pile_dems)
        else:
            try:
                plan = plan.refill(pile_topos, pile_dems)
                refills += 1
            except ValueError:     # pile shape drifted (shouldn't happen)
                plan = eng.plan(pile_topos, pile_dems)
        executes += 1
        keys.update(plan.stats.compile_keys)
        eng.last_plan = plan.stats
        solved = plan.execute(solver=eng.solver, **eng._solver_kw())

        idx = 0
        for fam_i, (name, _) in enumerate(fam_items):
            for frac in fractions:
                lbs, ubs, gaps, reach = [], [], [], []
                dead_n = 0
                for _ in range(trials):
                    s = solved[idx]
                    if lane_dead[idx]:
                        lb = ub = 0.0
                        dead_n += 1
                    else:
                        lb, ub = float(s.value), float(s.meta["ub"])
                    lbs.append(lb)
                    ubs.append(ub)
                    gaps.append((ub - lb) / max(ub, 1e-30))
                    reach.append(lane_reach[idx])
                    idx += 1
                q10, med, q90 = np.quantile(lbs, (0.1, 0.5, 0.9))
                points.append(DegradationPoint(
                    family=name, kind=kind, fraction=float(frac),
                    trials=trials, lb_q10=float(q10), lb_med=float(med),
                    lb_q90=float(q90), ub_mean=float(np.mean(ubs)),
                    gap_max=float(max(gaps)),
                    reachable_mean=float(np.mean(reach)),
                    dead_trials=dead_n))

    stats = {
        "executes": executes,
        "refills": refills,
        "compile_keys": tuple(sorted(keys)),
        "instances_per_execute": len(fam_items) * len(fractions) * trials,
        "families": [name for name, _ in fam_items],
        "kinds": tuple(kinds),
        "fractions": tuple(float(f) for f in fractions),
        "trials": trials,
        "engine": getattr(eng, "name", "certified"),
        "last_plan": plan.stats.as_dict() if plan is not None else None,
    }
    return DegradationResult(points=points, stats=stats)
