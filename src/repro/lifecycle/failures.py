"""Seeded failure-scenario generation: fleets of degraded topologies.

The operational questions about a wiring start where the paper's figures
stop: what happens when links cut, switches die, or a whole shared-risk
group (one VL2 aggregation class, one power feed) goes down together?
This module turns one base ``Topology`` into a deterministic fleet of
degraded variants, one per (failure kind × failure fraction × trial):

* ``fail_links`` — each trial removes ``round(fraction * #links)`` links
  chosen uniformly without replacement (independent link failures).
* ``fail_switches`` — removes ``round(fraction * N)`` switches: their
  rows/columns zero and their servers strand (``Topology.degrade``).
* ``fail_srg`` — correlated failures: removes ``round(fraction *
  #groups)`` whole shared-risk groups.  ``srg_from_labels`` builds the
  default grouping — one group per label class (so on VL2 a single draw
  can take out the entire aggregation layer); unlabeled topologies fall
  back to singleton groups (== switch failures).

Graceful degradation is a contract, not an accident: every scenario keeps
the base node count (rows zero, nodes never disappear), so a whole fleet
of mixed failure kinds lands in ONE ``BatchPlan`` bucket and later rounds
``refill`` the same compiled program.  Unroutable demand is the solver
layer's job (``mcf.drop_disconnected`` / engines' ``on_disconnected``) —
generation never crashes on a disconnected draw.

Determinism: ``scenario_fleet`` seeds each trial's generator as
``default_rng((seed, kind_id, fraction_index, trial))``, so the same
arguments always reproduce the identical fleet, independent of iteration
order or how many fractions/trials surround a given scenario.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.graphs import Topology

__all__ = ["Scenario", "fail_links", "fail_switches", "fail_srg",
           "srg_from_labels", "scenario_fleet", "FAIL_KINDS"]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One degraded variant of a base topology.

    ``topo`` has the SAME node count as the base (dead switches are zeroed
    rows, not removed) — that is what lets a whole fleet share one
    ``BatchPlan`` bucket.  ``server_fraction`` is the share of the base's
    servers still attached (stranded servers were zeroed by
    ``Topology.degrade``); demand reachability on top of the survivors is
    the solver layer's ``reachable_fraction``.
    """

    topo: Topology
    kind: str                       # FAIL_KINDS key that produced this
    fraction: float                 # requested failure fraction
    trial: int = 0
    seed: int = 0                   # fleet seed (0 for direct fail_* calls)
    failed_links: int = 0           # links removed (direct cuts only)
    dead_switches: tuple[int, ...] = ()
    server_fraction: float = 1.0    # surviving servers / base servers


def _server_fraction(base: Topology, degraded: Topology) -> float:
    total = int(base.servers.sum())
    return 1.0 if total == 0 else float(degraded.servers.sum()) / total


def fail_links(topo: Topology, fraction: float,
               rng: np.random.Generator) -> Scenario:
    """Remove ``round(fraction * #links)`` links uniformly at random
    (parallel-capacity pairs count once; the whole pair capacity cuts)."""
    if not 0.0 <= fraction <= 1.0:
        raise ValueError(f"fraction must be in [0, 1], got {fraction}")
    iu, iv = np.nonzero(np.triu(topo.cap, 1) > 0)
    k = int(round(fraction * len(iu)))
    mask = np.ones((topo.n, topo.n), dtype=bool)
    if k:
        pick = rng.choice(len(iu), size=k, replace=False)
        mask[iu[pick], iv[pick]] = False
        mask[iv[pick], iu[pick]] = False
    degraded = topo.degrade(link_mask=mask)
    return Scenario(topo=degraded, kind="links", fraction=fraction,
                    failed_links=k,
                    server_fraction=_server_fraction(topo, degraded))


def fail_switches(topo: Topology, fraction: float,
                  rng: np.random.Generator) -> Scenario:
    """Kill ``round(fraction * N)`` switches uniformly at random: their
    links cut and their servers strand."""
    if not 0.0 <= fraction <= 1.0:
        raise ValueError(f"fraction must be in [0, 1], got {fraction}")
    k = int(round(fraction * topo.n))
    dead = (np.sort(rng.choice(topo.n, size=k, replace=False))
            if k else np.zeros(0, np.int64))
    degraded = topo.degrade(dead_switches=dead)
    return Scenario(topo=degraded, kind="switches", fraction=fraction,
                    dead_switches=tuple(int(d) for d in dead),
                    server_fraction=_server_fraction(topo, degraded))


def srg_from_labels(topo: Topology) -> list[np.ndarray]:
    """Default shared-risk grouping: one group per label class (VL2's
    ToR / aggregation / core layers each fail together — the paper's
    heterogeneous pools group by switch class the same way).  Unlabeled
    topologies degrade to singleton groups, i.e. plain switch failures."""
    if topo.labels is None:
        return [np.array([i], np.int64) for i in range(topo.n)]
    return [np.flatnonzero(topo.labels == v)
            for v in np.unique(topo.labels)]


def fail_srg(topo: Topology, fraction: float, rng: np.random.Generator,
             groups: Sequence[np.ndarray] | None = None) -> Scenario:
    """Correlated failure: kill ``round(fraction * #groups)`` whole
    shared-risk ``groups`` at once (default grouping:
    ``srg_from_labels``)."""
    if not 0.0 <= fraction <= 1.0:
        raise ValueError(f"fraction must be in [0, 1], got {fraction}")
    groups = srg_from_labels(topo) if groups is None else list(groups)
    if not groups:
        raise ValueError("fail_srg needs at least one shared-risk group")
    k = int(round(fraction * len(groups)))
    dead = np.zeros(0, np.int64)
    if k:
        pick = rng.choice(len(groups), size=k, replace=False)
        dead = np.unique(np.concatenate([np.asarray(groups[g], np.int64)
                                         for g in pick]))
    degraded = topo.degrade(dead_switches=dead)
    return Scenario(topo=degraded, kind="srg", fraction=fraction,
                    dead_switches=tuple(int(d) for d in dead),
                    server_fraction=_server_fraction(topo, degraded))


# kind name -> generator(topo, fraction, rng) -> Scenario; KIND ORDER IS
# PART OF THE SEEDING CONTRACT (scenario_fleet keys its rng streams by the
# kind's position here), so append new kinds — never reorder.
FAIL_KINDS: dict[str, Callable] = {
    "links": fail_links,
    "switches": fail_switches,
    "srg": fail_srg,
}


def scenario_fleet(topo: Topology, kind: str,
                   fractions: Sequence[float], trials: int,
                   seed: int = 0, **kind_kw) -> list[Scenario]:
    """The degraded fleet for one failure ``kind``: ``len(fractions) ×
    trials`` scenarios, fraction-major then trial order.

    Each scenario draws from its own ``default_rng((seed, kind_id,
    fraction_index, trial))`` stream — the same call always reproduces the
    identical fleet, and streams stay independent across kinds, fractions
    and trials.  ``kind_kw`` forwards to the generator (e.g. ``groups=``
    for ``"srg"``).
    """
    if kind not in FAIL_KINDS:
        raise ValueError(f"unknown failure kind {kind!r}; "
                         f"known: {list(FAIL_KINDS)}")
    if trials < 1:
        raise ValueError(f"need trials >= 1, got {trials}")
    kind_id = list(FAIL_KINDS).index(kind)
    fleet = []
    for fi, frac in enumerate(fractions):
        for t in range(trials):
            rng = np.random.default_rng((seed, kind_id, fi, t))
            sc = FAIL_KINDS[kind](topo, float(frac), rng, **kind_kw)
            fleet.append(dataclasses.replace(sc, trial=t, seed=seed))
    return fleet
