"""Lifecycle: what happens to a wiring after day one.

The paper designs topologies; this package keeps them honest over their
operational life — failures and growth — using the same certified-solver
and ``BatchPlan`` machinery as the design search::

    from repro.core.graphs import random_regular_graph
    from repro.lifecycle import degradation_surface, plan_expansion

    base = random_regular_graph(24, 5, seed=0, servers=3)
    surface = degradation_surface({"rrg": base}, trials=20)
    growth = plan_expansion(base, [[6], [6], [6]], max_recabled_links=3)

Modules: ``failures`` (seeded degraded-fleet generation: independent
link cuts, switch deaths, correlated shared-risk groups — node counts
preserved so a whole fleet shares one plan bucket), ``degradation``
(certified throughput-vs-failure-fraction surfaces, one
``BatchPlan.execute`` per failure kind with ``refill`` keeping compile
keys shared), ``expansion`` (Jellyfish incremental growth under a
``max_recabled_links`` budget, with a certified lb trajectory that is
monotone non-decreasing by construction).  Driver:
``benchmarks/lifecycle_bench.py``; worked example:
``examples/survive_and_grow.py``.
"""
from repro.lifecycle.degradation import (  # noqa: F401
    DegradationPoint, DegradationResult, degradation_surface,
)
from repro.lifecycle.expansion import (  # noqa: F401
    Attachment, ExpansionResult, ExpansionSpace, ExpansionStep,
    attach_new_switches, plan_expansion, recabled_links,
)
from repro.lifecycle.failures import (  # noqa: F401
    FAIL_KINDS, Scenario, fail_links, fail_srg, fail_switches,
    scenario_fleet, srg_from_labels,
)
