import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
  * proof of SPMD coherence — .lower().compile() on the 16x16 single-pod and
    2x16x16 multi-pod meshes (sharding mismatches / unsupported collectives
    fail here);
  * memory_analysis() of the REAL (scanned) program — per-chip bytes;
  * roofline terms — FLOPs / HBM bytes / collective wire bytes per chip.
    cost_analysis() counts while bodies once (no trip count), so costs come
    from small FULLY-UNROLLED probe compiles extrapolated linearly in
    (num_layers, accum[, seq for the attention-free ssm]) — exact for
    homogeneous stacks; see launch/hlostats.py.

CLI:  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k \
          --mesh both --out experiments/dryrun
      python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, SHAPES, applicable_shapes,
                           expert_parallel_ok, get_config)
from repro.launch import hlostats
from repro.launch.mesh import dp_size, make_production_mesh, model_axis_size
from repro.models import layers as mlayers
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.optim import AdamW, cosine_schedule
from repro.parallel import sharding as shrules

MICRO_TOKENS_PER_DP = 8_192      # grad-accum sizing target


def pick_accum(shape, dp: int) -> int:
    if shape.kind != "train":
        return 1
    per_dp = max(shape.global_batch // dp, 1)
    micro_per_dp = max(1, MICRO_TOKENS_PER_DP // shape.seq_len)
    return max(1, per_dp // micro_per_dp)


# --------------------------------------------------------------------------
# input ShapeDtypeStructs + shardings
# --------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, shape, accum: int):
    b, s = shape.global_batch, shape.seq_len
    lead = (accum, b // accum) if shape.kind == "train" else (b,)
    i32, f32 = jnp.int32, jnp.float32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.frontend == "patch":
        p = cfg.frontend_len
        out = {
            "tokens": jax.ShapeDtypeStruct(lead + (s - p,), i32),
            "patch_embeds": jax.ShapeDtypeStruct(lead + (p, cfg.frontend_dim),
                                                 f32),
        }
        if cfg.mrope_sections is not None:
            out["positions"] = jax.ShapeDtypeStruct(lead + (3, s), i32)
    else:
        out = {"tokens": jax.ShapeDtypeStruct(lead + (s,), i32)}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct(lead + (s,), i32)
    return out


def batch_shardings(batch, mesh, kind: str, with_model: bool = False):
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = ("pod", "data", "model") if with_model else ("pod", "data")
    dp = tuple(a for a in axes if a in mesh.axis_names)

    def one(path, leaf):
        bdim = 1 if kind == "train" else 0   # [accum, B, ...] vs [B, ...]
        spec = [None] * leaf.ndim
        if leaf.shape[bdim] % (np.prod([mesh.shape[a] for a in dp])) == 0:
            spec[bdim] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch)


# --------------------------------------------------------------------------
# cell construction
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    fn: object
    args: tuple
    in_shardings: tuple
    accum: int
    donate: tuple = ()


def effective_dp(cfg: ModelConfig, shape, mesh) -> int:
    if shape.kind == "train" and cfg.sharding_profile == "fsdp":
        return mesh.size          # batch over every axis
    return dp_size(mesh)


def build_cell(cfg: ModelConfig, shape, mesh, accum: int | None = None) -> Cell:
    if shape.kind != "train":
        # serving weights are bf16 (standard practice; halves weight HBM);
        # the fsdp profile applies to training only (the serving cache needs
        # the model axis for its seq dim)
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16",
                                  sharding_profile="2d")
    profile = cfg.sharding_profile if shape.kind == "train" else "2d"
    rules = shrules.ShardingRules.profile(profile)
    shard = shrules.make_shard_fn(mesh, rules)
    ep = expert_parallel_ok(cfg, model_axis_size(mesh))
    accum = pick_accum(shape, effective_dp(cfg, shape, mesh)) \
        if accum is None else accum
    model = model_lib.get_model(cfg)

    params = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    p_specs = shrules.state_specs(params, mesh, "param", expert_parallel=ep)
    batch = batch_struct(cfg, shape, accum)
    b_specs = batch_shardings(batch, mesh, shape.kind,
                              with_model=(profile == "fsdp"))

    if shape.kind == "train":
        opt = AdamW(lr=cosine_schedule(3e-4, 100, 10_000))
        step = model_lib.make_train_step(cfg, opt, shard, accum=accum)
        opt_state = jax.eval_shape(opt.init, params)
        o_specs = shrules.state_specs(opt_state, mesh, "opt",
                                      expert_parallel=ep)
        return Cell(step, (params, opt_state, batch),
                    (p_specs, o_specs, b_specs), accum, donate=(0, 1))
    if shape.kind == "prefill":
        step = model_lib.make_prefill_step(cfg, max_len=shape.seq_len, shard=shard)
        return Cell(step, (params, batch), (p_specs, b_specs), accum)
    # decode: one new token against a cache of seq_len
    step = model_lib.make_decode_step(cfg, shard=shard)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    c_specs = shrules.state_specs(cache, mesh, "cache")
    return Cell(step, (params, cache, batch["tokens"]),
                (p_specs, c_specs, b_specs["tokens"]), accum, donate=(1,))


def lower_cell(cell: Cell, mesh):
    with mesh:
        return jax.jit(cell.fn, in_shardings=cell.in_shardings,
                       donate_argnums=cell.donate).lower(*cell.args)


# --------------------------------------------------------------------------
# cost probes (unrolled, small L [, small T for ssm], extrapolated)
# --------------------------------------------------------------------------

def _probe_cfg(cfg: ModelConfig, num_layers: int) -> ModelConfig:
    return dataclasses.replace(cfg, num_layers=num_layers)


def _probe_shape(shape, seq_len: int | None = None):
    if seq_len is None:
        return shape
    return dataclasses.replace(shape, seq_len=seq_len)


def _compile_cost(cfg, shape, mesh, accum):
    cell = build_cell(cfg, shape, mesh, accum=accum)
    with mlayers.unrolled_scans():
        lowered = lower_cell(cell, mesh)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    coll = hlostats.parse_collectives(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "ici": coll.ici_bytes,
        "dcn": coll.dcn_bytes,
    }


def _lincombine(c_small, c_big, x_small, x_big, x_target):
    """Linear extrapolation per metric dict."""
    out = {}
    for k in c_small:
        slope = (c_big[k] - c_small[k]) / (x_big - x_small)
        out[k] = c_small[k] + slope * (x_target - x_small)
    return out


def probe_costs(cfg: ModelConfig, shape, mesh) -> dict:
    """Per-chip {flops, bytes, ici, dcn} for the full cell, via unrolled
    probes + linear extrapolation in (L, accum[, T]).

    Train probes run at accum=1 with global_batch reduced to ONE microbatch
    (B/accum), so "micro" costs are measured at the real microbatch size;
    the accum pair (A=1 vs A=2 at small L) isolates the optimizer/fixed
    part, and the total is opt + accum * micro(L_full)."""
    accum = pick_accum(shape, effective_dp(cfg, shape, mesh))
    cycle = max(len(cfg.block_pattern), 1)
    l1, l2 = 1 * cycle, 2 * cycle
    if shape.kind == "train":
        mshape = dataclasses.replace(shape, global_batch=shape.global_batch
                                     // accum)
    else:
        mshape = shape
    if cfg.family == "ssm" and shape.kind != "decode":
        # attention-free: costs are linear in T as well -> probe small T
        t1, t2 = 256, 512
        c11 = _compile_cost(_probe_cfg(cfg, l1), _probe_shape(mshape, t1), mesh, 1)
        c21 = _compile_cost(_probe_cfg(cfg, l2), _probe_shape(mshape, t1), mesh, 1)
        c12 = _compile_cost(_probe_cfg(cfg, l1), _probe_shape(mshape, t2), mesh, 1)
        c22 = _compile_cost(_probe_cfg(cfg, l2), _probe_shape(mshape, t2), mesh, 1)
        ct1 = _lincombine(c11, c21, l1, l2, cfg.num_layers)
        ct2 = _lincombine(c12, c22, l1, l2, cfg.num_layers)
        micro = _lincombine(ct1, ct2, t1, t2, mshape.seq_len)
        a1 = c11
    else:
        c1 = _compile_cost(_probe_cfg(cfg, l1), mshape, mesh, 1)
        c2 = _compile_cost(_probe_cfg(cfg, l2), mshape, mesh, 1)
        micro = _lincombine(c1, c2, l1, l2, cfg.num_layers)
        a1 = c1
    if shape.kind != "train" or accum == 1:
        return micro
    # split out the optimizer/fixed part: F(A) = opt + A*micro, probed at
    # (l1, same microbatch, A=2) -> opt = 2*F(A=1) - F(A=2)
    a1_shape = _probe_shape(mshape, 256 if cfg.family == "ssm" else None)
    a2_shape = dataclasses.replace(a1_shape,
                                   global_batch=2 * a1_shape.global_batch)
    a2 = _compile_cost(_probe_cfg(cfg, l1), a2_shape, mesh, 2)
    out = {}
    for k in micro:
        d_micro = a2[k] - a1[k]                 # one extra microbatch (l1)
        opt_k = max(a1[k] - d_micro, 0.0)       # optimizer + fixed part
        out[k] = opt_k + accum * max(micro[k] - opt_k, 0.0)
    return out


# --------------------------------------------------------------------------
# cell report
# --------------------------------------------------------------------------

def analytic_memory(cfg: ModelConfig, shape, mesh, accum: int) -> dict:
    """Per-chip TPU-dtype memory estimate (the CPU backend's
    memory_analysis() promotes bf16 buffers to f32 and inserts whole-buffer
    convert copies, overstating bf16-heavy programs by up to ~2x; this is
    the true-dtype accounting the 16GB verdict uses).  All model/optimizer
    state is fully sharded over the whole mesh (2D param sharding), saved
    activations are seq-sharded over "model"."""
    chips = mesh.size
    dp, tp = dp_size(mesh), model_axis_size(mesh)
    n = cfg.param_count()
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    out = {}
    if shape.kind == "train":
        # f32 params + grads + adam m,v = 16 bytes/param, fully sharded
        out["state"] = 16.0 * n / chips
        mb = max(b // accum // dp, 1)               # seqs per dp-row
        out["saved_acts"] = cfg.num_layers * mb * s * d * 2.0 / tp
        # per-layer working set: ~6 full-seq activation copies (bf16) +
        # one attention panel (f32) for attention archs
        work = 6.0 * mb * s * d * 2.0
        if cfg.num_heads:
            heads_eff = -(-cfg.num_kv_heads // tp) * \
                (cfg.num_heads // cfg.num_kv_heads)
            work += 2.0 * mb * heads_eff * min(s, 1024) * s * 4.0
        out["workspace"] = work
        out["cache"] = 0.0
    else:
        out["state"] = 2.0 * n / chips              # bf16 serving weights
        mb = max(b // dp, 1)
        if cfg.family == "ssm":
            hn = cfg.num_rwkv_heads * cfg.rwkv_head_dim ** 2
            out["cache"] = cfg.num_layers * mb * (hn // tp * 4.0 + 2 * d * 2.0)
        elif cfg.family == "hybrid":
            rec = sum(k == "rec" for k in cfg.layer_kinds)
            attn = cfg.num_layers - rec
            out["cache"] = mb * (
                rec * (cfg.d_rnn_ * 4.0 + 3 * cfg.d_rnn_ * 2.0)
                + attn * cfg.local_window * cfg.num_kv_heads
                * cfg.head_dim_ * 2 * 2.0)
        else:
            out["cache"] = (cfg.num_layers * mb * (s / tp)
                            * cfg.num_kv_heads * cfg.head_dim_ * 2 * 2.0)
        if shape.kind == "prefill":
            out["saved_acts"] = 0.0
            out["workspace"] = 8.0 * mb * s * d * 2.0 / tp + \
                2.0 * mb * s * 1024 * 4.0
        else:
            out["saved_acts"] = 0.0
            out["workspace"] = 64.0 * mb * d * 2.0 + mb * (s / tp) * 4.0 * 64
    out["total"] = sum(out.values()) + 1.0e9        # +1GB runtime slack
    return out


def model_flops(cfg: ModelConfig, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode counts 2*N_active per
    token (forward only)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch        # decode: one token per seq


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                skip_probes: bool = False, profile: str | None = None) -> dict:
    cfg = get_config(arch)
    if profile:
        cfg = dataclasses.replace(cfg, sharding_profile=profile)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    nchips = mesh.size

    t0 = time.time()
    cell = build_cell(cfg, shape, mesh)
    lowered = lower_cell(cell, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ma = compiled.memory_analysis()
    report = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": nchips, "accum": cell.accum,
        "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
        "bytes_per_chip": {
            "arguments": int(ma.argument_size_in_bytes),
            "output": int(ma.output_size_in_bytes),
            "temp": int(ma.temp_size_in_bytes),
            "peak": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes),
        },
    }
    am = analytic_memory(cfg, shape, mesh, cell.accum)
    report["tpu_bytes_per_chip"] = {k: int(v) for k, v in am.items()}
    # the CPU backend promotes bf16 buffers to f32 (verified on the
    # mistral decode cell), so the 16GB verdict uses the true-dtype
    # analytic accounting; the raw CPU numbers are kept above.
    report["fits_16g"] = bool(am["total"] < 16e9)
    if not skip_probes:
        costs = probe_costs(cfg, shape, mesh)      # per chip
        terms = hlostats.roofline_terms(costs["flops"], costs["bytes"],
                                        hlostats.CollectiveStats(
                                            ici_bytes=costs["ici"],
                                            dcn_bytes=costs["dcn"]))
        mf = model_flops(cfg, shape)
        hlo_total = costs["flops"] * nchips
        report.update({
            "per_chip": {k: float(v) for k, v in costs.items()},
            "roofline": {k: (v if isinstance(v, str) else float(v))
                         for k, v in terms.items()},
            "model_flops": mf,
            "useful_flops_ratio": mf / hlo_total if hlo_total else 0.0,
        })
    return report


def iter_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in applicable_shapes(cfg.family):
            yield arch, shape_name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--profile", default=None, choices=[None, "2d", "fsdp"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    meshes = {"pod1": [False], "pod2": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    for arch, shape_name in cells:
        for mp in meshes:
            tag = f"{arch}_{shape_name}_{'pod2' if mp else 'pod1'}"
            try:
                rep = dryrun_cell(arch, shape_name, mp,
                                  skip_probes=args.skip_probes,
                                  profile=args.profile)
            except Exception as e:  # noqa: BLE001 - report and continue
                rep = {"arch": arch, "shape": shape_name,
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": f"{type(e).__name__}: {e}"}
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rep, f, indent=2)
            ok = "FAIL" if "error" in rep else "ok"
            extra = rep.get("error", "")[:120] if "error" in rep else (
                f"peak={rep['bytes_per_chip']['peak']/1e9:.2f}GB "
                f"compile={rep['compile_s']}s"
                + (f" bottleneck={rep['roofline']['bottleneck']}"
                   if "roofline" in rep else ""))
            print(f"[{ok}] {tag}: {extra}", flush=True)


if __name__ == "__main__":
    main()
