"""Batched serving driver: prefill a prompt batch, decode greedily.

Same code path for the CPU smoke configs and the production mesh; decode
runs one jitted step per token over a preallocated KV cache (ring-buffer /
recurrent state for the hybrid / ssm archs).

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-medium \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import model as model_lib


def generate(cfg, params, prompts: np.ndarray, gen: int,
             temperature: float = 0.0, seed: int = 0):
    """prompts [B, P] -> tokens [B, P+gen].  Greedy if temperature == 0."""
    model = model_lib.get_model(cfg)
    b, p = prompts.shape
    max_len = p + gen
    prefill = jax.jit(model_lib.make_prefill_step(cfg, max_len))
    decode = jax.jit(model_lib.make_decode_step(cfg))

    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    out = [jnp.asarray(prompts)]
    key = jax.random.PRNGKey(seed)

    def pick(logits, key):
        lg = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab_size,
                       logits, -jnp.inf)
        if temperature > 0:
            return jax.random.categorical(key, lg / temperature, axis=-1)
        return jnp.argmax(lg, axis=-1)

    tok = pick(logits, key)
    for i in range(gen):
        out.append(tok[:, None])
        if i == gen - 1:
            break
        logits, cache = decode(params, cache, tok[:, None].astype(jnp.int32))
        key, sub = jax.random.split(key)
        tok = pick(logits, sub)
    return np.asarray(jnp.concatenate(out, axis=1))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = model_lib.get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen, args.temperature,
                    args.seed)
    dt = time.time() - t0
    tps = args.batch * args.gen / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print("sample:", toks[0, -min(16, args.gen):].tolist())
    return {"tokens": toks, "tok_per_s": tps}


if __name__ == "__main__":
    main()
