"""End-to-end training driver (fault-tolerant: checkpoint/restart/elastic).

Runs on anything from this CPU container (smoke-sized config) to the
production mesh (full config; same code path — only --arch/--smoke and the
mesh flags change).  Features exercised here:

  * deterministic counter-based data (any host can build any shard),
  * grad accumulation + per-layer remat,
  * AdamW + cosine schedule + clipping,
  * atomic checkpoints every --ckpt-every steps; --resume restarts from the
    newest complete checkpoint, including across a mesh change (elastic
    re-shard via checkpoint.restore_checkpoint(shardings=...)),
  * optional int8 error-feedback cross-pod gradient compression
    (--pod-compress, multi-pod mesh only).

Example (CPU, ~100M-param smoke config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --smoke \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, latest_step, restore_checkpoint
from repro.configs import get_config, get_smoke
from repro.data import make_batch
from repro.models import layers as mlayers
from repro.models import model as model_lib
from repro.optim import AdamW, cosine_schedule
from repro.parallel import sharding as shrules


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--pod-compress", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the production multi-pod mesh (dry-run env)")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    shard = mlayers.no_shard
    npod = 1
    unshard_pod = None
    if args.multi_pod:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=True)
        npod = mesh.shape["pod"]
        rules = shrules.ShardingRules.default(dp_axes=("data",))
        shard = shrules.make_shard_fn(mesh, rules)
        if args.pod_compress:
            from jax.sharding import NamedSharding, PartitionSpec as P

            def unshard_pod(x):
                # replicate ONLY the pod dim; param dims stay as they are
                spec = P(None, *([P.UNCONSTRAINED] * (x.ndim - 1)))
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, spec))

    model = model_lib.get_model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps))
    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    if args.pod_compress:
        opt_state["ef_error"] = model_lib.init_ef_error(params, npod)

    train_step = model_lib.make_train_step(
        cfg, opt, shard, accum=args.accum,
        pod_compress=args.pod_compress, npod=npod, unshard_pod=unshard_pod)
    jstep = jax.jit(train_step, donate_argnums=(0, 1))

    start = 0
    ckpt = Checkpointer(args.ckpt_dir, args.ckpt_every) if args.ckpt_dir \
        else None
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        template = {"params": params, "opt_state": opt_state,
                    "data_step": np.zeros((), np.int64)}
        start, state = restore_checkpoint(args.ckpt_dir, template)
        params, opt_state = state["params"], state["opt_state"]
        start = int(state["data_step"])
        print(f"resumed from step {start}")

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = make_batch(cfg, args.batch, args.seq, step, args.seed,
                           accum=args.accum)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = jstep(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({dt:.1f}s)", flush=True)
        if ckpt is not None:
            ckpt.maybe_save(step + 1, {"params": params,
                                       "opt_state": opt_state,
                                       "data_step": np.int64(step + 1)})
    out = {"first_loss": losses[0], "last_loss": losses[-1],
           "steps": len(losses)}
    print(f"done: loss {out['first_loss']:.4f} -> {out['last_loss']:.4f}")
    return out


if __name__ == "__main__":
    main()
