"""Production meshes.

Single pod: (data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16,
model=16) = 512 chips; "pod" is the outer data-parallel axis whose gradient
hop rides the DCN (and is where optim.compress applies).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_size", "model_axis_size"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_size(mesh: jax.sharding.Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def model_axis_size(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape["model"]
