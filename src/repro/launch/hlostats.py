"""HLO-text collective accounting + roofline math (TPU v5e constants).

Collective wire-bytes per chip are estimated from the partitioned HLO using
the standard ring-algorithm factors on each op's (per-shard) shape:

    all-gather          out_bytes * (g-1)/g
    all-reduce          2 * bytes * (g-1)/g
    reduce-scatter      out_bytes * (g-1)          (out is the scattered part)
    all-to-all          bytes * (g-1)/g
    collective-permute  bytes

Ops are attributed to the DCN (cross-pod) when their replica group contains
members whose device ids differ by >= 256 (pods are the outermost 256-chip
blocks of the 512-device mesh).

NOTE cost_analysis() and this parser both see a while-loop body ONCE; the
dry-run handles trip counts by probing small fully-unrolled programs and
extrapolating (launch/dryrun.py), so parse_collectives here is applied to
those unrolled probes.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["V5E", "Hardware", "CollectiveStats", "parse_collectives",
           "roofline_terms"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    peak_flops: float          # bf16 FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    dcn_bw: float              # bytes/s per chip cross-pod


# per the assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
# DCN: 25 GB/s/chip is a typical multi-pod provision (noted in DESIGN.md).
V5E = Hardware(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9, dcn_bw=25e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# iota format: replica_groups=[G,S]<=[d0,d1,...](T(perm))?
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{\{(\d+),(\d+)\}")


def _iota_first_group(m) -> tuple[int, list[int]]:
    """Materialise the first replica group of an iota-format spec.
    Groups are reshape(transpose(arange(prod(dims)).reshape(dims), perm),
    [G, S]) rows — all groups have the same stride structure, so the first
    row is enough to classify pod-crossing."""
    import numpy as np
    g, s = int(m.group(1)), int(m.group(2))
    dims = [int(x) for x in m.group(3).split(",")]
    arr = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(4):
        arr = arr.transpose([int(x) for x in m.group(4).split(",")])
    rows = arr.reshape(g, s)
    return s, rows[0].tolist()


@dataclasses.dataclass
class CollectiveStats:
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    by_op: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, op: str, wire: float, is_dcn: bool) -> None:
        self.count += 1
        self.by_op[op] = self.by_op.get(op, 0.0) + wire
        if is_dcn:
            self.dcn_bytes += wire
        else:
            self.ici_bytes += wire


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str, pod_stride: int = 256) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.groups()
        bytes_ = _shape_bytes(dtype, dims)
        g = 2
        gm = _GROUPS_RE.search(line)
        members: list[int] = []
        if gm:
            members = [int(x) for x in gm.group(1).split(",") if x.strip()]
            g = max(len(members), 2)
        else:
            im = _IOTA_RE.search(line)
            if im:
                g, members = _iota_first_group(im)
                g = max(g, 2)
        st = _SRC_TGT_RE.search(line)
        if st:
            members = [int(st.group(1)), int(st.group(2))]
        is_dcn = any(abs(a - b) >= pod_stride
                     for a in members for b in members)
        if op == "all-gather":
            wire = bytes_ * (g - 1) / g
        elif op == "all-reduce":
            wire = 2 * bytes_ * (g - 1) / g
        elif op == "reduce-scatter":
            wire = bytes_ * (g - 1)
        elif op == "all-to-all":
            wire = bytes_ * (g - 1) / g
        else:                                  # collective-permute
            wire = bytes_
        stats.add(op, wire, is_dcn)
    return stats


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll: CollectiveStats, hw: Hardware = V5E) -> dict:
    """The three §Roofline terms, in seconds, plus the verdict."""
    t_compute = flops_per_chip / hw.peak_flops
    t_memory = bytes_per_chip / hw.hbm_bw
    t_ici = coll.ici_bytes / hw.ici_bw
    t_dcn = coll.dcn_bytes / hw.dcn_bw
    t_coll = t_ici + t_dcn
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll, "ici_s": t_ici, "dcn_s": t_dcn}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom
    # overlap-free step time bound and the achievable-fraction-of-peak
    terms["step_bound_s"] = max(t_compute, t_memory, t_coll)
    terms["roofline_fraction"] = (
        t_compute / terms["step_bound_s"] if terms["step_bound_s"] > 0 else 0)
    return terms
