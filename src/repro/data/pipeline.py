"""Deterministic, shard-aware synthetic token pipeline.

Design goals (the large-scale runnability story):

* **Counter-based determinism** — batch(step, example_index) is a pure
  function of (seed, step, example_index) via numpy Philox streams.  There
  is no shared cursor: any host can materialise any example of any step.
* **Straggler / elastic friendliness** — because assignment is
  step-indexed, a restarted or re-sharded job (different host count, or a
  backup host covering a straggler) regenerates exactly the stream it needs;
  the only checkpoint state is the integer ``step``.
* **Learnable structure** — tokens follow a noisy order-1 autoregression
  over a hashed alphabet, so the LM loss decreases measurably within a few
  hundred steps (used by examples/train_lm.py), while stats stay stationary.

The VLM/audio frontends are stubs per the assignment: ``make_batch``
supplies precomputed patch/frame embeddings drawn from the same counter
streams (the backbone is what we build; the encoder is out of scope).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["SyntheticLM", "make_batch"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def _rng(self, step: int, index: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, index]))

    def example(self, step: int, index: int) -> np.ndarray:
        """One sequence of ``seq_len + 1`` tokens (inputs + shifted labels)."""
        rng = self._rng(step, index)
        v = self.vocab_size
        x = np.empty(self.seq_len + 1, np.int32)
        x[0] = rng.integers(v)
        # noisy affine AR(1) over the vocab ring: learnable but non-trivial
        mult = 6364136223846793005 % v or 1
        noise = rng.integers(0, max(v // 64, 2), size=self.seq_len)
        for t in range(self.seq_len):
            x[t + 1] = (x[t] * mult + 17 + noise[t]) % v
        return x

    def shard_indices(self, host_id: int, num_hosts: int) -> np.ndarray:
        """The example indices this host owns (contiguous blocks)."""
        per = self.global_batch // num_hosts
        return np.arange(host_id * per, (host_id + 1) * per)

    def batch(self, step: int, host_id: int = 0,
              num_hosts: int = 1) -> dict[str, np.ndarray]:
        idx = self.shard_indices(host_id, num_hosts)
        seqs = np.stack([self.example(step, int(i)) for i in idx])
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


def make_batch(cfg: ModelConfig, batch_size: int, seq_len: int, step: int,
               seed: int = 0, accum: int = 1) -> dict[str, np.ndarray]:
    """Full train batch for an architecture, including frontend stubs.
    Leaves are shaped [accum, batch_size/accum, ...]."""
    mb = batch_size // accum
    pipe = SyntheticLM(cfg.vocab_size, seq_len, batch_size, seed)
    out = pipe.batch(step)

    if cfg.frontend == "patch":
        # VLM: a patch-embedding prefix replaces part of the text sequence
        p = cfg.frontend_len
        rng = np.random.default_rng(np.random.SeedSequence([seed, step, 977]))
        out["tokens"] = out["tokens"][:, : seq_len - p]
        patch = rng.standard_normal(
            (batch_size, p, cfg.frontend_dim)).astype(np.float32)
        out["patch_embeds"] = patch
        labels = np.concatenate(
            [np.full((batch_size, p), -1, np.int32),
             out["labels"][:, : seq_len - p]], axis=1)
        out["labels"] = labels
        if cfg.mrope_sections is not None:
            out["positions"] = _mrope_positions(batch_size, p, seq_len)

    def resh(x):
        return x.reshape((accum, mb) + x.shape[1:])

    return {k: resh(v) for k, v in out.items()}


def _mrope_positions(batch: int, prefix: int, seq_len: int) -> np.ndarray:
    """Qwen2-VL style (t, h, w) position ids: the patch prefix is a square
    grid at t=0; text tokens advance t with h = w = t."""
    side = max(int(np.sqrt(prefix)), 1)
    t = np.zeros(seq_len, np.int32)
    h = np.zeros(seq_len, np.int32)
    w = np.zeros(seq_len, np.int32)
    for i in range(prefix):
        h[i], w[i] = divmod(i, side)
    text = np.arange(seq_len - prefix, dtype=np.int32) + side
    t[prefix:] = text
    h[prefix:] = text
    w[prefix:] = text
    pos = np.stack([t, h, w])                       # [3, S]
    return np.broadcast_to(pos, (batch, 3, seq_len)).copy()
