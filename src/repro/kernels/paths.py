"""k-shortest path-set enumeration from converged (min,+) distances.

The routing-restricted solvers (``repro.core.routing``) need, per (s, t)
pair, the k shortest *simple* paths as a static-shape tensor they can jit
over.  This module produces that tensor host-side with one dense
tensorized dynamic program — the same (min,+) relaxation the APSP
backends run, lifted from the tropical semiring to its k-best extension:

1. **k-best walk lengths.**  ``D[u, t, 0:K']`` holds the K' shortest
   walk lengths u -> t using walks of at most ``max_hops`` hops.  The
   Bellman recurrence over the k-min semiring is exact on walk
   *multisets* — every walk decomposes uniquely as (first hop, shorter
   walk), so ``D' = kmin_v (w[u, v] + D[v, t, :])`` (plus the empty walk
   at u == t, level 0) converges in ``max_hops`` rounds.  One round is a
   dense ``[N, N, N·K']`` broadcast + partition — the k-best analogue of
   one (min,+) squaring step.
2. **Deviation tables.**  At the fixed point, a stable argsort of each
   (u, t) row's candidate multiset maps every level to its unique
   (next hop, sub-level) decomposition — the SP-DAG next-hop membership
   test ``dist[u, t] == w[u, v] + dist[v, t]`` at level 0, extended to k
   levels (Yen-style deviations ride the same table: level j deviates
   from level j-1 exactly where their (next hop, sub-level) choices
   split).
3. **Lock-step extraction.**  All ``N² × K'`` walks are materialised
   simultaneously, one hop per step, by fancy-indexed gathers into the
   deviation tables (``max_hops`` numpy steps total — no per-path Python
   loop).
4. **Simplicity filter.**  Walks with a repeated node are discarded and
   the first k *simple* walks per pair are kept, so every emitted path
   is simple, starts at s, ends at t, uses only real positive-capacity
   edges, and per-pair lengths are non-decreasing in k
   (``tests/test_routing.py`` property-tests all four on random graphs,
   padded matrices included).  ``K' = 2k + 2`` walk levels are searched
   by default, so the result is exactly the k shortest simple paths
   unless more than k + 2 non-simple walks interleave them (rare on hop
   metrics, where any loop costs >= 2 extra hops); the set is always a
   valid (possibly conservative) k-shortest path set, which is all the
   lower-bound solvers require.

Everything here is host-side numpy: enumeration happens once per
instance at plan-pack time (like bucket padding), and only the padded
``[pairs, k, max_hops + 1]`` int32 tensor enters the jitted solvers.
"""
from __future__ import annotations

import numpy as np

__all__ = ["k_shortest_paths", "path_hops", "path_edge_counts", "_INF"]

_INF = 1.0e18   # non-edge sentinel, matches repro.core.apsp._INF


def _hop_weights(cap: np.ndarray) -> np.ndarray:
    """Hop-metric weights: 1 on positive-capacity edges, _INF elsewhere
    (including the diagonal — an empty walk is not an edge)."""
    cap = np.asarray(cap)
    w = np.where(cap > 0, 1.0, _INF).astype(np.float32)
    np.fill_diagonal(w, _INF)
    return w


def _k_best_walks(w: np.ndarray, kp: int, max_hops: int) -> np.ndarray:
    """K'-best walk lengths ``D[u, t, 0:kp]`` over <= max_hops hops."""
    n = w.shape[0]
    d = np.full((n, n, kp), _INF, np.float32)
    idx = np.arange(n)
    d[idx, idx, 0] = 0.0
    for _ in range(max_hops):
        # cand[u, t, :] = kp smallest of {w[u, v] + D[v, t, j]}
        m = (w[:, :, None, None] + d[None, :, :, :])        # [u, v, t, j]
        m = m.transpose(0, 2, 1, 3).reshape(n, n, n * kp)   # [u, t, v*j]
        cand = np.partition(m, kp - 1, axis=-1)[:, :, :kp]
        cand.sort(axis=-1)
        new = cand
        # the empty walk at u == t occupies level 0 and shifts the rest
        diag = new[idx, idx, : kp - 1].copy()
        new[idx, idx, 1:] = diag
        new[idx, idx, 0] = 0.0
        if np.array_equal(new, d):
            break
        d = new
    return d


def _deviation_tables(w: np.ndarray,
                      d: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per (u, t, level): the unique (next hop NH, sub-level SR)
    decomposition, from a stable argsort of the candidate multiset (ties
    split deterministically by (v, j) index — the same tie order at
    every level, so distinct levels always decompose into distinct
    walks)."""
    n, _, kp = d.shape
    m = (w[:, :, None, None] + d[None, :, :, :])
    m = m.transpose(0, 2, 1, 3).reshape(n, n, n * kp)
    order = np.argsort(m, axis=-1, kind="stable")[:, :, :kp]
    nh = (order // kp).astype(np.int32)
    sr = (order % kp).astype(np.int32)
    # u == t: level 0 is the empty walk; level j >= 1 is candidate j - 1
    idx = np.arange(n)
    nh_d = nh[idx, idx, : kp - 1].copy()
    sr_d = sr[idx, idx, : kp - 1].copy()
    nh[idx, idx, 1:] = nh_d
    sr[idx, idx, 1:] = sr_d
    nh[idx, idx, 0] = idx   # self; level 0 at u == t is never walked
    sr[idx, idx, 0] = 0
    return nh, sr


def k_shortest_paths(cap: np.ndarray, k: int,
                     max_hops: int, *, walk_levels: int | None = None
                     ) -> np.ndarray:
    """k-shortest simple path sets for every ordered pair of ``cap``.

    Returns int32 ``paths[N, N, k, max_hops + 1]``: ``paths[s, t, j]`` is
    the j-th shortest simple path's node sequence (hop metric, <=
    ``max_hops`` hops), padded with -1 past its end; fully -1 when fewer
    than j + 1 simple paths exist within the hop budget (s == t rows are
    always -1).  Per pair, emitted path lengths are non-decreasing in j
    and level 0 is a true shortest path whenever t is reachable from s
    within ``max_hops`` hops.

    ``walk_levels`` (default ``2k + 2``) is how many k-best *walk*
    levels are searched before the simplicity filter; raise it if a
    dense graph interleaves many looping walks among the short simple
    ones.
    """
    cap = np.asarray(cap)
    n = cap.shape[0]
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if max_hops < 1:
        raise ValueError(f"max_hops must be >= 1, got {max_hops}")
    kp = walk_levels if walk_levels is not None else 2 * k + 2
    kp = max(kp, k)
    w = _hop_weights(cap)
    d = _k_best_walks(w, kp, max_hops)
    nh, sr = _deviation_tables(w, d)

    tgrid = np.broadcast_to(np.arange(n)[None, :, None], (n, n, kp)).copy()
    sgrid = np.broadcast_to(np.arange(n)[:, None, None], (n, n, kp)).copy()
    cur = sgrid.copy()
    lev = np.broadcast_to(np.arange(kp)[None, None, :], (n, n, kp)).copy()
    exists = (d[sgrid, tgrid, lev] < _INF / 2) & (sgrid != tgrid)
    walks = np.full((n, n, kp, max_hops + 1), -1, np.int32)
    walks[..., 0] = np.where(exists, sgrid, -1)
    done = ~exists
    for h in range(max_hops):
        done = done | ((cur == tgrid) & (lev == 0))
        step = ~done
        nxt = nh[cur, tgrid, lev]
        nlev = sr[cur, tgrid, lev]
        walks[..., h + 1] = np.where(step, nxt, walks[..., h + 1])
        cur = np.where(step, nxt, cur)
        lev = np.where(step, nlev, lev)
    finished = exists & (cur == tgrid) & (lev == 0)

    # simplicity: no node repeats among the walk's real entries (pad -1
    # entries are remapped to unique sentinels so they never collide)
    pad_ids = n + np.arange(max_hops + 1, dtype=np.int32)
    nodes = np.where(walks >= 0, walks, pad_ids)
    nodes = np.sort(nodes, axis=-1)
    simple = np.all(np.diff(nodes, axis=-1) != 0, axis=-1)
    ok = finished & simple

    # keep the first k valid walks per pair (stable: preserves the
    # non-decreasing length order), blank the rest
    keep = np.argsort(~ok, axis=-1, kind="stable")[:, :, :k]
    out = np.take_along_axis(walks, keep[..., None], axis=2)
    kept_ok = np.take_along_axis(ok, keep, axis=-1)
    return np.where(kept_ok[..., None], out, -1).astype(np.int32)


def path_hops(paths: np.ndarray) -> np.ndarray:
    """Hop count per path (entries - 1), -1 for absent (-1-padded) paths."""
    real = (np.asarray(paths) >= 0).sum(axis=-1)
    return np.where(real > 0, real - 1, -1)


def path_edge_counts(paths: np.ndarray, n: int) -> np.ndarray:
    """Directed edge-use counts ``[n, n]`` summed over every real hop of
    every path — the host-side twin of the solvers' scatter-add (used by
    the path-LP cross-check and tests)."""
    p = np.asarray(paths)
    a, b = p[..., :-1], p[..., 1:]
    m = (a >= 0) & (b >= 0)
    out = np.zeros((n, n), np.int64)
    np.add.at(out, (a[m], b[m]), 1)
    return out
