"""Jit'd public wrappers around the Pallas kernels.

* pad/unpad to block multiples,
* interpret-mode dispatch: ``interpret=None`` auto-detects via
  ``jax.default_backend()`` (compiled kernels on TPU, the Pallas
  interpreter on CPU containers); pass an explicit bool to override,
* custom VJPs so kernels can sit inside differentiable code (the MCF dual
  solver differentiates through min-plus APSP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import minplus as _minplus
from repro.kernels import flash_attention as _flash
from repro.kernels import ref as _ref
from repro.kernels.minplus import resolve_interpret

__all__ = ["minplus_matmul", "flash_attention", "wkv_chunked", "INF",
           "resolve_interpret"]

INF = 1.0e38   # "infinity" edge weight that survives one add without overflow


def _pad_to(x: jax.Array, m0: int, m1: int, val: float) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)), constant_values=val)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def minplus_matmul(a: jax.Array, b: jax.Array, block: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """C = A (min,+) B with padding to block multiples.  Differentiable:
    the VJP routes cotangents through the argmin edges (ties split evenly),
    which is exactly the shortest-path-DAG subgradient the MCF solver needs.
    ``interpret=None`` auto-detects from the backend (compiled on TPU)."""
    m, k = a.shape
    _, n = b.shape
    if min(m, k, n) < block:      # tiny instances: reference is faster
        return _ref.minplus_matmul_ref(a, b)
    ap = _pad_to(a.astype(jnp.float32), block, block, INF)
    bp = _pad_to(b.astype(jnp.float32), block, block, INF)
    out = _minplus.minplus_matmul_pallas(ap, bp, bm=block, bn=block,
                                         bk=block, interpret=interpret)
    return out[:m, :n]


def _minplus_fwd(a, b, block, interpret):
    c = minplus_matmul(a, b, block, interpret)
    return c, (a, b, c)


def _minplus_bwd(block, interpret, res, g):
    a, b, c = res
    # mask[i, k, j] = 1 where A[i,k] + B[k,j] == C[i,j]; split ties evenly.
    # The tie tolerance must scale with the entries: the primal MCF solver
    # differentiates APSP at edge lengths spanning many orders of
    # magnitude, and an absolute 1e-6 would lump near-ties of tiny-length
    # paths into the "shortest" set.
    s = a[:, :, None] + b[None, :, :]
    tol = 1e-6 * jnp.maximum(jnp.abs(c[:, None, :]), 1e-6)
    mask = (s <= c[:, None, :] + tol).astype(jnp.float32)
    mask = mask / jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
    da = jnp.einsum("ikj,ij->ik", mask, g)
    db = jnp.einsum("ikj,ij->kj", mask, g)
    return da, db


minplus_matmul.defvjp(_minplus_fwd, _minplus_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Padded GQA flash attention.  q: [B, Lq, Hq, D]; k, v: [B, Lk, Hkv, D].

    Pads Lq/Lk up to tile multiples; padded keys are masked via lk_valid,
    padded query rows are discarded.  Falls back to the jnp reference for
    shapes smaller than one tile (e.g. single-token decode on tiny models,
    where a kernel launch would be all overhead).
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    if lq == 1 or lk < bk:
        return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    pq = (-lq) % bq
    pk = (-lk) % bk
    # pad queries at the FRONT so the causal diagonal stays aligned with the
    # end of the (unpadded) key sequence; padded keys go at the back and are
    # masked via lk_valid.
    qp = jnp.pad(q, ((0, 0), (pq, 0), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    out = _flash.flash_attention_pallas(
        qp, kp, vp, causal=causal, scale=scale, bq=bq, bk=bk,
        lk_valid=lk, interpret=interpret)
    return out[:, pq:]


def wkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
                u: jax.Array, interpret: bool = True) -> jax.Array:
    """Chunked WKV-6 via the Pallas kernel; pads T to the chunk size."""
    from repro.kernels import wkv as _wkv
    bh, t, n = r.shape
    pad = (-t) % _wkv.CHUNK
    if pad:
        # padded steps: k,v = 0 and log_w = 0 leave the state untouched
        r, k, v, log_w = (jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
                          for x in (r, k, v, log_w))
    out = _wkv.wkv_chunked_pallas(r, k, v, log_w, u, interpret=interpret)
    return out[:, :t]
