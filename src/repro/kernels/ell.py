"""ELL-packed Bellman-Ford APSP for degree-bounded graphs.

Dense APSP backends relax every (k, t) pair — on a degree-16 random
regular graph at N=8192 the weight matrix is >99% ``_INF`` sentinels and
both blocked Floyd-Warshall and repeated squaring burn nearly all their
work on non-edges.  This module packs the adjacency into a fixed-width
padded-ELL table — ``idx[N, d_max]`` int32 neighbor ids + ``wgt[N,
d_max]`` float32 lengths, pads at the END of each row with ``idx = own
row`` (a safe self-gather) and ``wgt = _INF`` — and closes it with
batched Bellman-Ford relaxation rounds.  Degree-bounded graphs make the
pad waste tiny and every shape static, so the kernel jits, vmaps over
solver lanes, and keys cleanly into the AOT compile cache.

**Table orientation.**  Row ``v`` lists the tails of edges INTO ``v``:
``idx[v, j] = u`` and ``wgt[v, j] = w(u -> v)``.  On the symmetric
capacity patterns the repo solves, in-neighbors equal out-neighbors and
only the weights are directional (``repro.core.apsp._pack_ell`` packs
the transpose for exactly this reason).

**The recurrence is row-pull, not column-push.**  The textbook update
``d[:, v] = min(d[:, v], min_u d[:, u] + w(u, v))`` gathers strided
COLUMNS of the distance carry — measured 25x slower than pulling whole
rows.  We carry the transpose ``m[t, s] = dist(s -> t)`` and relax a
tile of target rows at a time::

    m[t, :] = min(m[t, :], min_j wgt[t, j] + m[idx[t, j], :])

so every gather is ``d_max`` contiguous row reads.  Tiles are swept in
order within a round (Gauss-Seidel: later tiles see already-relaxed
rows), which only accelerates the monotone descent — the fixed point is
the exact shortest-path closure either way, reached in O(diameter)
rounds with a per-round convergence flag for early exit.

Flavors (mirroring ``repro.kernels.fw``):

* ``ell_bf_apsp`` — full (N, N) closure in one jitted program; what the
  ``"ell-bf"`` registry backend runs (jnp tiles off-TPU, the Pallas
  round on TPU or with explicit ``interpret=True``).
* ``ell_relax_round_pallas`` — ONE Jacobi relaxation round as a Pallas
  grid over target tiles, returning the new carry plus per-tile
  convergence flags.  Same fixed point as the Gauss-Seidel sweep.
* ``ell_bf_apsp_streamed`` — the frontier path: host-streamed source
  blocks.  Each block's ``(N, S)`` transposed carry converges
  independently (its own early exit) and lands in one preallocated host
  array, so peak memory is ONE N^2 f32 output + O(N x S) device state —
  this is what moves the 1.5 GB frontier from N=4096 to N>=16384.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.minplus import resolve_interpret

__all__ = ["ell_bf_apsp", "ell_bf_apsp_streamed", "ell_relax_round_pallas",
           "DEFAULT_TILE", "DEFAULT_BLOCK"]

_INF = 1.0e18      # == repro.core.apsp._INF (no circular import; test-pinned)
DEFAULT_TILE = 1024    # target rows per relaxation tile (CPU sweet spot)
DEFAULT_BLOCK = 1024   # source columns per streamed block


def _check_tables(idx: jax.Array, wgt: jax.Array) -> tuple[int, int]:
    if idx.ndim != 2 or idx.shape != wgt.shape:
        raise ValueError(f"ELL tables must be matching (N, d_max) arrays, "
                         f"got idx {idx.shape} / wgt {wgt.shape}")
    if not jnp.issubdtype(idx.dtype, jnp.integer):
        raise ValueError(f"ELL idx must be integer, got {idx.dtype}")
    return int(idx.shape[0]), int(idx.shape[1])


def _relax_tiles_jnp(m, idx, wgt, *, tile: int):
    """One Gauss-Seidel relaxation round over target tiles.  Returns
    (new carry, changed flag).  ``tile`` need not divide N: the trailing
    tile's dynamic slice clamps and overlaps already-relaxed rows, which
    re-applies an idempotent min — harmless to the fixed point."""
    n, d_max = idx.shape

    def relax_tile(ti, carry):
        m, changed = carry
        t0 = ti * tile
        mt = jax.lax.dynamic_slice_in_dim(m, t0, tile, axis=0)
        it = jax.lax.dynamic_slice_in_dim(idx, t0, tile, axis=0)
        wt = jax.lax.dynamic_slice_in_dim(wgt, t0, tile, axis=0)

        def slot(j, acc):
            # one contiguous row gather per ELL column: m[idx[t, j], :]
            return jnp.minimum(acc,
                               jnp.take(m, it[:, j], axis=0) + wt[:, j, None])

        new = jax.lax.fori_loop(0, d_max, slot, mt)
        changed = changed | jnp.any(new < mt)
        return jax.lax.dynamic_update_slice_in_dim(m, new, t0, axis=0), changed

    nt = -(-n // tile)
    return jax.lax.fori_loop(0, nt, relax_tile, (m, jnp.bool_(False)))


def _relax_round_kernel(m_ref, idx_ref, wgt_ref, o_ref, c_ref):
    m = m_ref[...]
    it = idx_ref[...]
    wt = wgt_ref[...]
    t = it.shape[0]
    mt = jax.lax.dynamic_slice_in_dim(m, pl.program_id(0) * t, t, axis=0)

    def slot(j, acc):
        return jnp.minimum(acc, jnp.take(m, it[:, j], axis=0) + wt[:, j, None])

    new = jax.lax.fori_loop(0, it.shape[1], slot, mt)
    o_ref[...] = new
    c_ref[...] = jnp.any(new < mt).reshape(1)


def ell_relax_round_pallas(m: jax.Array, idx: jax.Array, wgt: jax.Array, *,
                           tile: int = 256,
                           interpret: bool | None = None):
    """One Jacobi relaxation round as a Pallas grid over target tiles.

    Every tile reads the full pre-round carry (the grid is unordered, so
    tiles cannot see each other's updates within a round — unlike the
    sequential jnp sweep; both converge to the same closure).  Returns
    ``(new_m, changed[nt])`` where ``changed[i]`` is tile ``i``'s
    convergence flag — a tile that reports False has reached its fixed
    point.  ``tile`` must divide N here (the jnp flavor clamps instead);
    the whole carry sits in one block, so on real TPU hardware N x S
    must fit VMEM — CPU containers run the jnp flavor, and tests drive
    this path in interpret mode.
    """
    n, d_max = _check_tables(idx, wgt)
    if m.shape[0] != n:
        raise ValueError(f"carry has {m.shape[0]} rows, tables have {n}")
    if n % tile:
        raise ValueError(f"ell_relax_round_pallas: n={n} must be a multiple "
                         f"of tile={tile}")
    nt = n // tile
    s = m.shape[1]
    out_m, changed = pl.pallas_call(
        _relax_round_kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((n, s), lambda i: (0, 0)),
                  pl.BlockSpec((tile, d_max), lambda i: (i, 0)),
                  pl.BlockSpec((tile, d_max), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile, s), lambda i: (i, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n, s), jnp.float32),
                   jax.ShapeDtypeStruct((nt,), jnp.bool_)],
        interpret=resolve_interpret(interpret))(
            m.astype(jnp.float32), idx, wgt.astype(jnp.float32))
    return out_m, changed


def _bf_fixpoint(idx, wgt, m0, *, tile: int, max_rounds: int,
                 use_pallas: bool, interpret: bool | None):
    """Relax a transposed carry ``m0[t, s]`` to the shortest-path fixed
    point.  Traceable (no jit/donation here) so ``repro.core.apsp`` can
    inline it under the solvers' jit/vmap.  Returns (m, rounds)."""

    def round_(carry):
        m, _, rounds = carry
        if use_pallas:
            m, flags = ell_relax_round_pallas(m, idx, wgt, tile=tile,
                                              interpret=interpret)
            ch = jnp.any(flags)
        else:
            m, ch = _relax_tiles_jnp(m, idx, wgt, tile=tile)
        return m, ch, rounds + 1

    def cond(carry):
        return carry[1] & (carry[2] < max_rounds)

    m, _, rounds = jax.lax.while_loop(
        cond, round_, (m0.astype(jnp.float32), jnp.bool_(True),
                       jnp.int32(0)))
    return m, rounds


def _full_init(idx, wgt):
    """Transposed one-hop carry for ALL sources: m0[t, s] = w(s -> t),
    0 on the diagonal, _INF elsewhere.  Row t of the (incoming) tables
    scatters exactly the w(s -> t) entries; pads self-scatter _INF."""
    n = idx.shape[0]
    rows = jnp.arange(n)
    m0 = jnp.full((n, n), _INF, jnp.float32)
    m0 = m0.at[rows[:, None], idx].min(wgt.astype(jnp.float32))
    return m0.at[rows, rows].set(0.0)


def ell_bf_apsp_impl(idx, wgt, *, tile: int = DEFAULT_TILE,
                     max_rounds: int | None = None,
                     use_pallas: bool = False,
                     interpret: bool | None = None):
    """Traceable full closure: (distances d[s, t], rounds executed).
    The carry is relaxed transposed (see module docstring) and flipped
    back on return; symmetric inputs make the flip a no-op in value."""
    n, d_max = idx.shape
    tile = max(1, min(tile, n))
    if max_rounds is None:
        max_rounds = n
    m0 = _full_init(idx, wgt)
    m, rounds = _bf_fixpoint(idx, wgt, m0, tile=tile, max_rounds=max_rounds,
                             use_pallas=use_pallas, interpret=interpret)
    return m.T, rounds


@functools.partial(jax.jit,
                   static_argnames=("tile", "max_rounds", "use_pallas",
                                    "interpret"))
def ell_bf_apsp(idx: jax.Array, wgt: jax.Array, *, tile: int = DEFAULT_TILE,
                max_rounds: int | None = None, use_pallas: bool = False,
                interpret: bool | None = None):
    """All-pairs shortest paths of an ELL-packed graph in one jitted
    program: ``(d[s, t], rounds)``.  ``max_rounds`` (default N, a safe
    cap — convergence takes at most diameter + 1 rounds) is static and
    part of the compile key.  Entries with no path stay ~``_INF``
    (compare against ``_INF / 2``, never equality)."""
    _check_tables(idx, wgt)
    return ell_bf_apsp_impl(idx, wgt, tile=tile, max_rounds=max_rounds,
                            use_pallas=use_pallas, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("s0", "block"))
def _block_init(idx, wgt, *, s0: int, block: int):
    """Transposed one-hop carry for sources [s0, s0 + block): scatter the
    in-block columns of every target row's incoming edges."""
    n = idx.shape[0]
    col = idx - s0
    inblk = (col >= 0) & (col < block)
    m0 = jnp.full((n, block), _INF, jnp.float32)
    m0 = m0.at[jnp.arange(n)[:, None], jnp.clip(col, 0, block - 1)].min(
        jnp.where(inblk, wgt.astype(jnp.float32), _INF))
    return m0.at[s0 + jnp.arange(block), jnp.arange(block)].set(0.0)


@functools.partial(jax.jit,
                   static_argnames=("tile", "max_rounds"),
                   donate_argnums=(2,))
def _block_solve(idx, wgt, m0, *, tile: int, max_rounds: int):
    return _bf_fixpoint(idx, wgt, m0, tile=tile, max_rounds=max_rounds,
                        use_pallas=False, interpret=None)


def ell_bf_apsp_streamed(idx, wgt, *, block: int = DEFAULT_BLOCK,
                         tile: int = DEFAULT_TILE,
                         max_rounds: int | None = None,
                         out: np.ndarray | None = None
                         ) -> tuple[np.ndarray, int]:
    """Memory-frugal full closure: stream source blocks through one
    compiled ``(N, block)`` fixed-point program, writing each converged
    block into a host array.  Returns ``(d[N, N] float32, max rounds
    over blocks)`` — each block early-exits at ITS OWN round count (the
    per-tile convergence contract at source-block granularity).

    Peak memory is the N^2 output + two (N, block) device carries
    (donated ping-pong) + the tables: at N=16384 / block=1024 that is
    ~1.3 GB where any all-device dense method needs >= 2 N^2 live.  The
    one-hop block init uses incoming tables only, so asymmetric weights
    (symmetric pattern) are handled exactly like the full-matrix path.
    """
    idx = jnp.asarray(idx)
    wgt = jnp.asarray(wgt)
    n, _ = _check_tables(idx, wgt)
    block = max(1, min(block, n))
    if n % block:
        raise ValueError(f"ell_bf_apsp_streamed: n={n} must be a multiple "
                         f"of block={block}")
    tile = max(1, min(tile, n))
    if max_rounds is None:
        max_rounds = n
    if out is None:
        out = np.empty((n, n), np.float32)
    elif out.shape != (n, n) or out.dtype != np.float32:
        raise ValueError(f"out must be a float32 ({n}, {n}) array")
    worst = 0
    for s0 in range(0, n, block):
        m0 = _block_init(idx, wgt, s0=s0, block=block)
        m, rounds = _block_solve(idx, wgt, m0, tile=tile,
                                 max_rounds=max_rounds)
        # m[t, s_local] = dist(s0 + s_local -> t): transpose into the
        # output's source-major rows on the host (a view; numpy copies
        # straight into the preallocated slab)
        out[s0:s0 + block, :] = np.asarray(m).T
        worst = max(worst, int(rounds))
    return out, worst
