"""Blocked Floyd-Warshall APSP: Pallas tiled kernels + a lax.fori fallback.

Repeated (min,+) squaring does ``log2(N)`` full tropical matmuls —
``O(N^3 log N)`` work and, on the pure-jnp path, an ``O(N^3)`` broadcast
per step.  Blocked Floyd-Warshall does the same closure in ONE ``O(N^3)``
pass over 128-aligned tiles with ``O(N^2)`` live memory, which is what
pushes the solvable-N frontier toward 10k switches.

Per pivot tile ``kk`` (classic 4-phase schedule):

1. **pivot block**: close ``D[kk, kk]`` with an in-tile Floyd-Warshall
   (``t`` sequential relaxations);
2. **row panel**:  ``D[kk, :] = min(D[kk, :], P (min,+) D[kk, :])``;
3. **col panel**:  ``D[:, kk] = min(D[:, kk], D[:, kk] (min,+) P)``;
4. **outer update**: ``D = min(D, D[:, kk] (min,+) D[kk, :])``.

Phases 2-4 applied to the pivot row/col/block itself are idempotent
(``P`` has a zero diagonal and is min-plus closed), so the outer update
runs over the whole matrix without masking.

Backend flavors (see ``repro.core.apsp`` for the registry):

* ``fw_apsp_pallas`` — the tiled kernel path (compiled on TPU; the Pallas
  interpreter is the explicit-``interpret=True`` escape hatch used by the
  property tests);
* ``fw_apsp_jnp`` — portable ``lax.fori_loop`` Floyd-Warshall (one
  ``O(N^2)`` relaxation per node).  Same algorithm family and identical
  distances; this is what CPU containers run, where the interpreter
  would be the bottleneck.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.minplus import resolve_interpret

__all__ = ["fw_apsp_pallas", "fw_apsp_jnp", "fw_tile_closure"]


def fw_tile_closure(d: jax.Array) -> jax.Array:
    """In-tile Floyd-Warshall closure of a square (t, t) block: t sequential
    relaxations ``d = min(d, d[:, k] + d[k, :])``.  Used for the pivot phase
    and as the single-tile fast path."""
    t = d.shape[0]

    def body(k, dd):
        row = jax.lax.dynamic_slice_in_dim(dd, k, 1, axis=0)   # (1, t)
        col = jax.lax.dynamic_slice_in_dim(dd, k, 1, axis=1)   # (t, 1)
        return jnp.minimum(dd, col + row)

    return jax.lax.fori_loop(0, t, body, d)


def _minplus_acc(acc: jax.Array, a: jax.Array, b: jax.Array,
                 chunk: int) -> jax.Array:
    """min(acc, A (min,+) B) with the k axis processed in small chunks so the
    3-D broadcast stays under VMEM limits (same scheme as the minplus
    kernel)."""
    t = a.shape[1]

    def body(i, o):
        a_c = jax.lax.dynamic_slice_in_dim(a, i * chunk, chunk, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(b, i * chunk, chunk, axis=0)
        return jnp.minimum(o, jnp.min(a_c[:, :, None] + b_c[None, :, :],
                                      axis=1))

    return jax.lax.fori_loop(0, t // chunk, body, acc)


def _pivot_kernel(d_ref, o_ref):
    o_ref[...] = fw_tile_closure(d_ref[...])


def _row_panel_kernel(p_ref, r_ref, o_ref, *, chunk: int):
    o_ref[...] = _minplus_acc(r_ref[...], p_ref[...], r_ref[...], chunk)


def _col_panel_kernel(c_ref, p_ref, o_ref, *, chunk: int):
    o_ref[...] = _minplus_acc(c_ref[...], c_ref[...], p_ref[...], chunk)


def _outer_kernel(d_ref, c_ref, r_ref, o_ref, *, chunk: int):
    o_ref[...] = _minplus_acc(d_ref[...], c_ref[...], r_ref[...], chunk)


@functools.partial(jax.jit, static_argnames=("t", "chunk", "interpret"))
def fw_apsp_pallas(w: jax.Array, *, t: int = 128, chunk: int = 8,
                   interpret: bool | None = None) -> jax.Array:
    """Blocked Floyd-Warshall closure of an (N, N) float32 weight matrix via
    Pallas tiles.  N must be a multiple of the tile size ``t`` (callers pad
    with the +inf sentinel; see ``repro.core.apsp``).  Entries are treated
    additively — any finite "infinity" sentinel survives the single adds."""
    n = w.shape[0]
    if w.ndim != 2 or w.shape[0] != w.shape[1]:
        raise ValueError(f"fw_apsp_pallas: square matrix required, got "
                         f"{w.shape}")
    if n % t:
        raise ValueError(f"fw_apsp_pallas: n={n} must be a multiple of the "
                         f"tile size t={t} (callers pad)")
    if t % chunk:
        raise ValueError(f"fw_apsp_pallas: t={t} must be a multiple of "
                         f"chunk={chunk}")
    interpret = resolve_interpret(interpret)
    nb = n // t
    d = w.astype(jnp.float32)
    if nb == 1:
        return fw_tile_closure(d)

    row_call = pl.pallas_call(
        functools.partial(_row_panel_kernel, chunk=chunk),
        grid=(nb,),
        in_specs=[pl.BlockSpec((t, t), lambda j: (0, 0)),
                  pl.BlockSpec((t, t), lambda j: (0, j))],
        out_specs=pl.BlockSpec((t, t), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=interpret)
    col_call = pl.pallas_call(
        functools.partial(_col_panel_kernel, chunk=chunk),
        grid=(nb,),
        in_specs=[pl.BlockSpec((t, t), lambda i: (i, 0)),
                  pl.BlockSpec((t, t), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((t, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, t), jnp.float32),
        interpret=interpret)
    outer_call = pl.pallas_call(
        functools.partial(_outer_kernel, chunk=chunk),
        grid=(nb, nb),
        in_specs=[pl.BlockSpec((t, t), lambda i, j: (i, j)),
                  pl.BlockSpec((t, t), lambda i, j: (i, 0)),
                  pl.BlockSpec((t, t), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret)
    pivot_call = pl.pallas_call(
        _pivot_kernel,
        out_shape=jax.ShapeDtypeStruct((t, t), jnp.float32),
        interpret=interpret)

    # one traced pivot step, rolled over kk with lax.fori_loop: a Python
    # loop here unrolls nb pivot/row/col/outer call groups into the trace
    # (32 at N=4096/t=128), multiplying trace + XLA compile wall for zero
    # runtime benefit — every block offset is already a dynamic slice
    def pivot_step(kk, d):
        piv = jax.lax.dynamic_slice(d, (kk * t, kk * t), (t, t))
        piv = pivot_call(piv)
        row = jax.lax.dynamic_slice(d, (kk * t, 0), (t, n))
        col = jax.lax.dynamic_slice(d, (0, kk * t), (n, t))
        # the row/col panels include the pivot block: min(W, P+W) there is
        # exactly P (zero diagonal), so no masking is needed
        row = row_call(piv, row)
        col = col_call(col, piv)
        d = jax.lax.dynamic_update_slice(d, row, (kk * t, 0))
        d = jax.lax.dynamic_update_slice(d, col, (0, kk * t))
        return outer_call(d, col, row)

    return jax.lax.fori_loop(0, nb, pivot_step, d)


@jax.jit
def fw_apsp_jnp(w: jax.Array) -> jax.Array:
    """Plain Floyd-Warshall: N sequential O(N^2) relaxations, O(N^2) live
    memory.  The portable flavor of the blocked-fw backend (CPU containers,
    CI) — identical distances to the tiled kernel."""
    return fw_tile_closure(w.astype(jnp.float32))
