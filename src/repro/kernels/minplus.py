"""Pallas TPU kernel: tropical (min,+) matrix multiply.

    C[i, j] = min_k ( A[i, k] + B[k, j] )

This is the inner loop of all-pairs-shortest-paths by repeated squaring —
the hot spot of the paper's throughput engine (dual MCF solver evaluates
APSP under evolving edge lengths every iteration).

TPU adaptation: the tropical semiring has no MXU support, so the kernel is
blocked exactly like a matmul (HBM -> VMEM tiles, 128-aligned so the VPU
lanes are fully used) but accumulates with elementwise add + min-reduce on
the VPU.  The k-dimension is the innermost grid axis; the output block lives
in VMEM across the k-loop and is min-accumulated in place.  Within a block,
k is processed in small chunks so the 3-D broadcast (bm, chunk, bn) stays
well under VMEM limits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["minplus_matmul_pallas", "resolve_interpret"]

_NEG_INF_SAFE = 3.0e38   # "+inf" stand-in that survives adds (python float)


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> auto-detect: run the compiled kernel on TPU, the Pallas
    interpreter everywhere else (CPU containers, CI)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int, chunk: int):
    """One (bm, bn) output tile; min-accumulate over the k grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, _NEG_INF_SAFE)

    a = a_ref[...]          # (bm, bk)
    b = b_ref[...]          # (bk, bn)

    def body(i, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, i * chunk, chunk, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(b, i * chunk, chunk, axis=0)
        cand = jnp.min(a_c[:, :, None] + b_c[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    o_ref[...] = jax.lax.fori_loop(0, bk // chunk, body, o_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "chunk",
                                             "interpret"))
def minplus_matmul_pallas(a: jax.Array, b: jax.Array, *,
                          bm: int = 128, bn: int = 128, bk: int = 128,
                          chunk: int = 8,
                          interpret: bool | None = None) -> jax.Array:
    """Tropical matmul via pallas_call.  Inputs are (M, K) and (K, N) float32;
    entries >= 1e38 are treated as +inf.  Shapes must be multiples of the
    block sizes (callers pad; see ops.minplus_matmul).  ``interpret=None``
    auto-detects from the JAX backend (compiled on TPU, interpreter
    elsewhere)."""
    interpret = resolve_interpret(interpret)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(
            f"minplus_matmul_pallas: inner dimensions disagree: "
            f"a.shape={a.shape} (K={k}) vs b.shape={b.shape} (K={k2})")
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"minplus_matmul_pallas: shapes must be multiples of the block "
            f"sizes: a.shape={a.shape}, b.shape={b.shape} with blocks "
            f"(bm={bm}, bn={bn}, bk={bk}); callers pad (see ops.minplus_matmul)")
    if bk % chunk:
        raise ValueError(
            f"minplus_matmul_pallas: bk={bk} must be a multiple of "
            f"chunk={chunk}")

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_minplus_kernel, bk=bk, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
