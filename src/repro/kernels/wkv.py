"""Pallas TPU kernel: chunked RWKV-6 WKV with data-dependent decay.

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t

Grid: (batch*heads, num_chunks) with the chunk axis innermost and the
per-(b,h) state S held in a VMEM scratch across chunk steps.  Within a chunk
of C=32 tokens everything is dense linear algebra on (C, n) / (n, n) tiles:

    lcw   = cumsum(log w)                      (VPU)
    A     = (r * e^{lcw_ex}) @ (k * e^{-lcw})^T   masked strictly-lower (MXU)
    o     = A @ v + (r.u.k) v + (r e^{lcw_ex}) @ S (MXU)
    S'    = e^{total} . S + (k e^{total-lcw})^T @ v (MXU)

The decay clamp (|log w| <= 2.5/step) bounds every exponent by C*2.5 = 80 <
log(3.4e38), so all math is float32-safe — same scheme as the pure-jnp
reference (models/rwkv6.py), which this kernel matches bit-for-bit up to
float summation order.

TPU adaptation note: the CUDA RWKV kernel is a per-token serial loop with
warp-level parallelism over channels; that shape is hostile to the MXU.  The
chunked reformulation trades a little redundant decay math for dense
(C x n)x(n x n) matmuls — the standard linear-attention TPU mapping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv_chunked_pallas", "CHUNK"]

CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                nc: int, n: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    rr = r_ref[0].astype(jnp.float32)          # (C, n)
    kk = k_ref[0].astype(jnp.float32)
    vv = v_ref[0].astype(jnp.float32)
    ww = w_ref[0].astype(jnp.float32)          # log decay, negative
    u = u_ref[0].astype(jnp.float32)           # (1, n) bonus

    lcw = jnp.cumsum(ww, axis=0)
    lcw_ex = lcw - ww
    r_t = rr * jnp.exp(lcw_ex)
    k_t = kk * jnp.exp(-lcw)

    a = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (C, C)
    c = a.shape[0]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    a = jnp.where(col < row, a, 0.0)           # strictly lower

    s = s_ref[...]                             # (n, n) carried state
    o = jax.lax.dot_general(a, vv, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    diag = jnp.sum(rr * u * kk, axis=1, keepdims=True)
    o = o + diag * vv
    o = o + jax.lax.dot_general(r_t, s, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)

    total = lcw[-1:, :]                        # (1, n)
    k_s = kk * jnp.exp(total - lcw)
    s_ref[...] = s * jnp.exp(total).T + jax.lax.dot_general(
        k_s, vv, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_chunked_pallas(r: jax.Array, k: jax.Array, v: jax.Array,
                       log_w: jax.Array, u: jax.Array,
                       interpret: bool = True) -> jax.Array:
    """r,k,v,log_w: [BH, T, n] float32 (T % CHUNK == 0); u: [BH?, n] or [n].
    Returns o [BH, T, n].  State starts at zero (prefill semantics; the
    jnp reference handles carried state across calls)."""
    bh, t, n = r.shape
    assert t % CHUNK == 0, (t, CHUNK)
    nc = t // CHUNK
    if u.ndim == 1:
        u = jnp.broadcast_to(u[None], (bh, n))
    u = u[:, None, :]                           # (BH, 1, n)

    grid = (bh, nc)
    return pl.pallas_call(
        functools.partial(_wkv_kernel, nc=nc, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, CHUNK, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, CHUNK, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, CHUNK, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, n), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, CHUNK, n), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
      log_w.astype(jnp.float32), u.astype(jnp.float32))
