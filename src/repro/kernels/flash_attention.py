"""Pallas TPU kernel: FlashAttention-2 style fused attention with GQA.

Online-softmax attention over (128, 128) q/k tiles held in VMEM; the logits
matmul and the probs @ V matmul hit the MXU (dot_general with
preferred_element_type=float32), the running max / normaliser updates run on
the VPU.  Scratch (acc, m, l) persists across the k grid axis (innermost, so
Pallas keeps the output tile resident in VMEM between k steps).  The m / l
running statistics are stored lane-replicated in (bq, 128) VMEM tiles, the
layout real TPU flash kernels use.

Causal masking is static: key position = kk*bk + iota, query position =
qi*bq + iota + (lk_valid - lq), mask = kpos <= qpos and kpos < lk_valid.
Tiles that are fully masked are skipped with pl.when (no MXU work) — for
causal attention this halves the compute; it is the TPU analogue of a CUDA
kernel's early tile exit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1.0e30   # python float so the kernel closes over no tracers
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, lq: int, lk_valid: int,
                  causal: bool, scale: float, num_k_blocks: int):
    qi = pl.program_id(2)
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # static-shape position grids for masking
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (lk_valid - lq)
    k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # tile-level skip: any unmasked element in this (q, k) tile?
    needed = (kk * bk) < lk_valid
    if causal:
        needed = needed & ((kk * bk) <= (qi * bq + (bq - 1) + (lk_valid - lq)))

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32)     # (bq, d)
        k = k_ref[0, 0, :, :].astype(jnp.float32)     # (bk, d)
        v = v_ref[0, 0, :, :].astype(jnp.float32)     # (bk, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = k_pos < lk_valid
        if causal:
            mask = mask & (k_pos <= q_pos)
        logits = jnp.where(mask, logits, _NEG_INF)

        m_prev = m_ref[:, 0:1]                        # (bq, 1)
        l_prev = l_ref[:, 0:1]
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kk == num_k_blocks - 1)
    def _finalize():
        l = l_ref[:, 0:1]
        safe_l = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0, :, :] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "bq", "bk", "lk_valid", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           lk_valid: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """q: [B, Lq, Hq, D]; k, v: [B, Lk, Hkv, D], Hq % Hkv == 0.

    Lq % bq == 0 and Lk % bk == 0 (ops.flash_attention pads).  ``lk_valid``
    masks padded key positions (defaults to Lk).  Returns [B, Lq, Hq, D].
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    assert lq % bq == 0 and lk % bk == 0, (lq, lk, bq, bk)
    g = hq // hkv
    scale = float(scale) if scale is not None else 1.0 / (d ** 0.5)
    lk_valid = lk if lk_valid is None else lk_valid

    nq, nk = lq // bq, lk // bk
    grid = (b, hq, nq, nk)

    qt = q.transpose(0, 2, 1, 3)   # [B, Hq, Lq, D]
    kt = k.transpose(0, 2, 1, 3)   # [B, Hkv, Lk, D]
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, bq=bq, bk=bk, lq=lq, lk_valid=lk_valid,
            causal=causal, scale=scale, num_k_blocks=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, qi, kk: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, qi, kk: (bb, h // g, kk, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, qi, kk: (bb, h // g, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, h, qi, kk: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
