"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["minplus_matmul_ref", "flash_attention_ref", "wkv_ref"]


def minplus_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[i,j] = min_k A[i,k] + B[k,j] — direct broadcast reference."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        scale: float | None = None,
                        bias: jax.Array | None = None) -> jax.Array:
    """Grouped-query attention reference.

    q: [B, Lq, Hq, D]; k, v: [B, Lk, Hkv, D]; Hq % Hkv == 0.
    Softmax in float32; output cast back to q.dtype.
    """
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, lq, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if causal:
        # positions: query i attends to keys j <= i + (lk - lq)
        qi = jnp.arange(lq)[:, None] + (lk - lq)
        kj = jnp.arange(lk)[None, :]
        mask = kj <= qi
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    if bias is not None:
        logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(b, lq, hq, d).astype(q.dtype)


def wkv_ref(r, k, v, log_w, u):
    """Serial WKV-6 oracle (independent of any chunking).

    r,k,v,log_w: [BH, T, n] f32; u: [n] or [BH, n].  Returns o [BH, T, n].
        S_t = diag(w_t) S_{t-1} + k_t v_t^T;  o_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
    """
    bh, t, n = r.shape
    if u.ndim == 1:
        u = jnp.broadcast_to(u[None], (bh, n))

    def step(s, xs):
        rt, kt, vt, wt = xs                      # [BH, n]
        kv = kt[:, :, None] * vt[:, None, :]     # [BH, n, n]
        o = jnp.einsum("bn,bnm->bm", rt, s + u[:, :, None] * kv)
        s = s * jnp.exp(wt)[:, :, None] + kv
        return s, o

    s0 = jnp.zeros((bh, n, n), jnp.float32)
    xs = tuple(x.transpose(1, 0, 2) for x in (r, k, v, log_w))
    _, o = jax.lax.scan(step, s0, xs)
    return o.transpose(1, 0, 2)
