"""The paper's contribution: high-throughput topology design + flow engines.

Modules: graphs (Topology + generation), traffic (named demand patterns),
engine (unified ThroughputEngine registry + declarative sweeps), plan
(BatchPlan: bucketed/chunked/device-sharded batch execution core), lp (exact
HiGHS max-concurrent-flow), mcf (JAX dual solver on min-plus APSP: certified
upper bounds), primal (Frank-Wolfe shortest-path-routing primal solver:
certified lower bounds, fused lb/ub brackets), bounds (Thm 1 / Cerf d* /
Eqn 1-2), decompose (T = C.U/(f.D.AS)), heterogeneous (Figs 3-7 drivers),
vl2 (Fig 11), fabric (topology -> collective bandwidth for the training
runtime).  The design layer on top — fleet search over wirings through
one BatchPlan per round — lives in ``repro.design``.

The public entry points are re-exported here::

    from repro.core import Topology, get_engine, run_sweep, Sweep, traffic

    topo = graphs.random_regular_graph(40, 10, seed=0, servers=5)
    dem = traffic.make("permutation", topo.servers, seed=1)
    result = get_engine("exact").solve(topo, dem)   # ThroughputResult
"""
from repro.core import (  # noqa: F401
    bounds, decompose, engine, fabric, graphs, heterogeneous, lp, mcf,
    plan, primal, traffic, vl2,
)
from repro.core.engine import (  # noqa: F401
    CertifiedEngine, DualEngine, ExactLPEngine, PrimalEngine, Sweep,
    SweepPoint, ThroughputEngine, ThroughputResult, as_engine, get_engine,
    run_sweep, run_sweeps,
)
from repro.core.graphs import Topology  # noqa: F401
from repro.core.plan import BatchPlan, PlanStats  # noqa: F401
