"""The paper's contribution: high-throughput topology design + flow engines.

Modules: graphs (topology generation), traffic (demand matrices), lp (exact
HiGHS max-concurrent-flow), mcf (JAX dual solver on min-plus APSP), bounds
(Thm 1 / Cerf d* / Eqn 1-2), decompose (T = C.U/(f.D.AS)), heterogeneous
(Figs 3-7 drivers), vl2 (Fig 11), fabric (topology -> collective bandwidth
for the training runtime).
"""
from repro.core import (  # noqa: F401
    bounds, decompose, fabric, graphs, heterogeneous, lp, mcf, traffic, vl2,
)
