"""Fabric design + collective cost model — the paper as a training feature.

The paper designs data-center fabrics; a multi-pod training job consumes one:
the cross-pod (DCN) hop of a hierarchical all-reduce runs over exactly the
kind of heterogeneous switch fabric the paper optimises.  This module

  1. designs a pod-interconnect fabric from a heterogeneous switch inventory
     using the paper's two rules (attach end-points in proportion to port
     count; wire the rest uniformly at random), and
  2. turns any such fabric into an *achievable collective bandwidth* figure
     via max-concurrent-flow — the number the roofline's cross-pod collective
     term divides by, instead of a flat per-link constant.

Pods attach with ``nics_per_pod`` unit-capacity links each; throughput is per
unit demand, so a collective pattern with per-pod demand d GB moves at
``theta * link_gbps`` GB/s per unit, i.e. finishes in d / (theta*link_gbps).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import engine as engine_mod
from repro.core import graphs

__all__ = [
    "FabricDesign", "design_fabric", "collective_demand",
    "collective_bandwidth", "compare_with_traditional",
]


@dataclasses.dataclass(frozen=True)
class FabricDesign:
    topology: graphs.Topology    # switch-level fabric; servers[i] = #pod NICs
    pod_switch: np.ndarray       # [num_pods * nics] switch hosting each pod NIC
    num_pods: int
    nics_per_pod: int
    link_gbps: float             # capacity of one unit link in GB/s


def _pod_demand_to_switch(design: FabricDesign,
                          pod_dem: np.ndarray) -> np.ndarray:
    """Aggregate a pod-level demand matrix to switch level, splitting each
    pod's traffic evenly over its NICs."""
    n = design.topology.n
    dem = np.zeros((n, n))
    nic_sw = design.pod_switch.reshape(design.num_pods, design.nics_per_pod)
    for s in range(design.num_pods):
        for t in range(design.num_pods):
            if pod_dem[s, t] == 0:
                continue
            share = pod_dem[s, t] / (design.nics_per_pod ** 2)
            for a in nic_sw[s]:
                for b in nic_sw[t]:
                    if a != b:
                        dem[a, b] += share
    return dem


def design_fabric(port_counts: Sequence[int], num_pods: int,
                  nics_per_pod: int = 1, link_gbps: float = 25.0,
                  seed: int = 0, proportional: bool = True) -> FabricDesign:
    """Design a pod-interconnect fabric from a switch inventory.

    proportional=True  — the paper's rule: pod NICs spread over switches in
                         proportion to port count; rest wired random.
    proportional=False — the 'traditional' strawman: pod NICs packed onto the
                         smallest switches only (ToR-style), rest random.
    """
    ports = np.asarray(port_counts, np.int64)
    n = len(ports)
    total_nics = num_pods * nics_per_pod
    if total_nics >= ports.sum():
        raise ValueError("inventory too small for the pod count")
    if proportional:
        srv = graphs.distribute_servers(ports, total_nics, beta=1.0)
    else:
        srv = np.zeros(n, np.int64)
        order = np.argsort(ports)            # smallest switches first
        left = total_nics
        for i in order:
            take = min(left, ports[i] - 1)
            srv[i] = take
            left -= take
            if left == 0:
                break
        if left:
            raise ValueError("small switches cannot host all pod NICs")
    deg = ports - srv
    if deg.sum() % 2 != 0:
        deg = deg.copy()
        deg[int(np.argmax(deg))] -= 1
    cap = graphs._random_graph_cap(deg, seed, allow_multi=True)
    # NIC -> switch assignment, round-robin over the switch server slots
    pod_switch = np.repeat(np.arange(n), srv)
    rng = np.random.default_rng(seed + 1)
    pod_switch = rng.permutation(pod_switch)[:total_nics]
    topo = graphs.Topology(cap=cap, servers=srv, labels=None)
    return FabricDesign(topology=topo, pod_switch=pod_switch,
                        num_pods=num_pods, nics_per_pod=nics_per_pod,
                        link_gbps=link_gbps)


def collective_demand(num_pods: int, pattern: str) -> np.ndarray:
    """Pod-level demand matrix for one 'round' of a collective, normalised to
    1 unit per sending pod."""
    p = num_pods
    dem = np.zeros((p, p))
    if pattern == "ring":          # reduce-scatter/all-gather ring step
        for i in range(p):
            dem[i, (i + 1) % p] = 1.0
    elif pattern == "alltoall":    # MoE-style dispatch
        dem[:] = 1.0 / max(p - 1, 1)
        np.fill_diagonal(dem, 0.0)
    elif pattern == "allgather":   # everyone -> everyone, full copies
        dem[:] = 1.0
        np.fill_diagonal(dem, 0.0)
    else:
        raise ValueError(f"unknown pattern {pattern!r}")
    return dem


def collective_bandwidth(design: FabricDesign, pattern: str = "ring",
                         engine="exact") -> float:
    """Achievable per-pod bandwidth (GB/s) for the collective pattern: the
    max concurrent rate theta at which every pod can sustain its demand."""
    pod_dem = collective_demand(design.num_pods, pattern)
    dem = _pod_demand_to_switch(design, pod_dem)
    th = engine_mod.as_engine(engine).solve(design.topology, dem).throughput
    return th * design.link_gbps   # theta is per-unit-demand = per pod


def compare_with_traditional(port_counts: Sequence[int], num_pods: int,
                             nics_per_pod: int = 1, link_gbps: float = 25.0,
                             pattern: str = "ring", runs: int = 3,
                             seed0: int = 0,
                             engine="exact") -> dict[str, float]:
    """Paper-rule fabric vs ToR-style packing, mean over seeds."""
    out = {}
    for name, prop in (("paper", True), ("traditional", False)):
        vals = [collective_bandwidth(
            design_fabric(port_counts, num_pods, nics_per_pod, link_gbps,
                          seed0 + 101 * rr, proportional=prop),
            pattern, engine) for rr in range(runs)]
        out[name] = float(np.mean(vals))
    out["gain"] = out["paper"] / out["traditional"] - 1.0
    return out
