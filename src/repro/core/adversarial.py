"""Adversarial traffic: differentiable worst-case TM search over the hose
polytope.

Every sampled pattern in ``repro.core.traffic`` asks "how does this wiring
do on typical traffic?".  Jyothi et al. (arXiv 1402.2531) show that is the
wrong question for ranking topologies — rankings flip under near-worst-case
matrices, and the paper's own §3 bound is only meaningful against the worst
FEASIBLE demand.  This module searches for that demand:

* **Hose polytope, feasibility by construction.**  A hose-feasible TM has
  row sums ≤ servers[u] (no switch sources more than its servers can
  inject) and column sums ≤ servers[v].  Candidates are parameterized by
  free logits: ``softplus`` makes them positive, rows are scaled to
  EXACTLY the hose row caps, then columns are clipped down to the column
  caps — ending on the column clip (which only shrinks entries) leaves
  every emitted matrix inside the polytope, no projection step to verify
  after the fact.  Scaling rows UP to the cap matters: throughput is per
  unit demand, so an unconstrained adversary would just shrink the TM;
  saturated rows keep the search honest.
* **Descent ON throughput.**  ``mcf.solve_dual_demgrad_batch`` returns,
  along with each candidate's certified upper bound, the Danskin gradient
  of the converged bound w.r.t. the demand matrix (distances do not depend
  on demand, so it costs one extra APSP forward and no APSP backward).
  The gradient is pulled back through the hose reparameterization with
  ``jax.vjp`` and Adam steps the logits — gradient descent on log θ.
* **One ``BatchPlan.execute`` per round.**  The whole candidate fleet
  (lane 0 is the fixed uniform baseline, so the running minimum can never
  end up ABOVE the baseline) solves as one batched plan per round; round
  one builds the plan, later rounds ``refill`` it, and the final
  certification (primal lower bound on the argmin TM) rides the SAME plan
  — identical compile keys from the first round to the last, the contract
  ``repro.design`` pins.

``find_worst_tm`` is the entry point; ``traffic.make("adversarial", ...,
topo=...)`` and ``engine.get_engine("adversarial")`` wrap it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import traffic as traffic_mod
from repro.core.graphs import Topology
from repro.core.plan import BatchPlan

__all__ = ["hose_feasible", "hose_violation", "AdversarialResult",
           "find_worst_tm"]

# independent sub-streams per use, keyed like traffic._STRIDE_REST_KEY
_LOGITS_KEY = int.from_bytes(b"adv-logits", "little")
_BASELINE_KEY = int.from_bytes(b"adv-baseline", "little")


def _hose_feasible_jnp(logits: jax.Array, servers: jax.Array,
                       proj_iters: int) -> jax.Array:
    """Differentiable logits -> hose-feasible demand matrix.

    Alternating row-saturation / column-clip, ending on the clip: after
    the last row pass every row sums to its cap ``servers[u]`` exactly,
    and the final column pass multiplies columns by min(1, cap/colsum) —
    entries only shrink, so row sums stay ≤ cap while column sums land ≤
    cap.  Feasible after ONE iteration; more iterations push toward
    saturating both sides (a Sinkhorn-style sweep).  Zero-server rows,
    columns, and the diagonal are exactly zero.
    """
    servers = servers.astype(jnp.float32)
    n = servers.shape[0]
    live = servers > 0
    mask = (live[:, None] & live[None, :]) & ~jnp.eye(n, dtype=bool)
    x = jax.nn.softplus(logits) * mask
    eps = jnp.float32(1e-30)
    for _ in range(proj_iters):
        x = x * (servers / jnp.maximum(x.sum(axis=1), eps))[:, None]
        x = x * jnp.minimum(
            1.0, servers / jnp.maximum(x.sum(axis=0), eps))[None, :]
    return x


def hose_feasible(logits: np.ndarray, servers: np.ndarray,
                  proj_iters: int = 8) -> np.ndarray:
    """Host-facing wrapper of the differentiable hose reparameterization
    (see ``_hose_feasible_jnp``): [N, N] free logits -> a demand matrix
    with zero diagonal, row sums ≤ ``servers``, column sums ≤ ``servers``
    — by construction, for ANY logits."""
    return np.asarray(_fleet_project(
        jnp.asarray(logits, jnp.float32)[None],
        jnp.asarray(servers, jnp.float32), proj_iters)[0])


def hose_violation(dem: np.ndarray, servers: np.ndarray) -> float:
    """Worst hose-cap overshoot of ``dem`` (0.0 = feasible): the max over
    diagonal mass, row-sum excess, and column-sum excess, in flow units.
    The tests pin this ≈ 0 on every candidate the search emits."""
    dem = np.asarray(dem, np.float64)
    servers = np.asarray(servers, np.float64)
    return float(max(np.abs(np.diag(dem)).max(initial=0.0),
                     (dem.sum(axis=1) - servers).max(initial=0.0),
                     (dem.sum(axis=0) - servers).max(initial=0.0)))


@functools.partial(jax.jit, static_argnames=("proj_iters",))
def _fleet_project(logits: jax.Array, servers: jax.Array,
                   proj_iters: int) -> jax.Array:
    """[K, N, N] logits -> [K, N, N] hose-feasible demand matrices."""
    return jax.vmap(
        lambda lg: _hose_feasible_jnp(lg, servers, proj_iters))(logits)


@functools.partial(jax.jit, static_argnames=("proj_iters",))
def _fleet_pullback(logits: jax.Array, dem_grads: jax.Array,
                    servers: jax.Array, proj_iters: int) -> jax.Array:
    """Pull the solver's per-candidate demand cotangents back through the
    hose reparameterization: [K, N, N] d loss/d dem -> d loss/d logits."""
    def one(lg, ct):
        _, vjp = jax.vjp(
            lambda l: _hose_feasible_jnp(l, servers, proj_iters), lg)
        return vjp(ct)[0]
    return jax.vmap(one)(logits, dem_grads)


@dataclasses.dataclass(frozen=True)
class AdversarialResult:
    """Outcome of one worst-TM search.

    ``tm`` is the worst hose-feasible demand matrix found (switch-level,
    coarsened when the input topology carried server nodes) and
    ``lb``/``ub`` its certified throughput bracket: an explicit feasible
    flow routes ``tm`` at rate ≥ ``lb``, and no routing exceeds ``ub``.
    ``baseline_lb``/``baseline_ub`` bracket the uniform baseline TM
    (lane 0 of every round — the search minimum can never sit above it),
    and ``uniform_gap_pct`` = 100·(baseline_ub − ub)/baseline_ub is how
    much certified headroom the adversary destroyed.  ``history`` has one
    dict per round; ``stats`` carries the plan/execute accounting
    (``executes == search_executes + certify_executes`` with exactly one
    execute per search round and one certification); ``fleet`` keeps
    every emitted candidate TM when ``keep_fleet=True`` (for invariant
    checks), else ().
    """

    tm: np.ndarray
    lb: float
    ub: float
    baseline_lb: float
    baseline_ub: float
    uniform_gap_pct: float
    history: list[dict]
    stats: dict[str, Any]
    fleet: tuple[np.ndarray, ...] = ()


def find_worst_tm(topo: Topology, *, seed: int = 0, rounds: int = 4,
                  candidates: int = 8, lr_tm: float = 0.5,
                  proj_iters: int = 8, baseline: np.ndarray | None = None,
                  iters: int = 300, lr: float = 0.08, tol: float = 1e-3,
                  check_every: int = 25, backend: str | None = None,
                  interpret: bool | None = None,
                  devices: int | None = None,
                  max_lanes: int | None = None,
                  bucket: str | int | None = "pow2",
                  keep_fleet: bool = False) -> AdversarialResult:
    """Search the hose polytope for a demand matrix that minimises the
    topology's max-concurrent-flow throughput.

    ``topo`` must be a ``Topology`` with servers on ≥ 2 switches (the
    hose polytope is empty otherwise); a server-expanded topology is
    coarsened to switch level first.  ``candidates`` TMs are evaluated
    per round — lane 0 is the fixed ``baseline`` (default: the uniform
    random server permutation with this ``seed``), lanes 1.. are
    logits-parameterized and Adam-stepped (``lr_tm``) along the Danskin
    demand-gradient of the certified dual bound.  Every round is ONE
    ``BatchPlan.execute``; the plan is built once and ``refill``-ed, and
    the final primal certification of the argmin TM reuses it too, so
    the whole search holds compile keys fixed after round one.

    ``iters``/``lr``/``tol``/``check_every``/``backend``/``interpret``
    are the inner dual-solver knobs (defaults are tuned for ranking
    candidates cheaply, not for publication-grade brackets — raise
    ``iters`` for tighter certificates).  Returns an
    ``AdversarialResult``; seeded and deterministic.
    """
    if not isinstance(topo, Topology):
        raise ValueError(
            "find_worst_tm needs a Topology (the hose caps come from its "
            "per-switch server counts); got a bare capacity matrix")
    if rounds < 1 or candidates < 2:
        raise ValueError("need rounds >= 1 and candidates >= 2 (lane 0 is "
                         f"the baseline), got rounds={rounds}, "
                         f"candidates={candidates}")
    topo = topo.coarsen()
    servers = np.asarray(topo.servers, np.int64)
    n = len(servers)
    if int((servers > 0).sum()) < 2:
        raise ValueError(
            "adversarial search needs servers on >= 2 switches, got "
            f"{int((servers > 0).sum())} (the hose polytope has no "
            "off-diagonal demand otherwise)")
    if baseline is None:
        baseline = traffic_mod.random_permutation(
            servers, (seed, _BASELINE_KEY))
    baseline = np.asarray(baseline, np.float64)
    if baseline.shape != (n, n):
        raise ValueError(f"baseline TM must be [{n}, {n}] (switch-level, "
                         "post-coarsening), got "
                         f"{baseline.shape}")

    rng = np.random.default_rng((seed, _LOGITS_KEY))
    logits = jnp.asarray(
        rng.normal(0.0, 1.0, size=(candidates - 1, n, n)), jnp.float32)
    servers_j = jnp.asarray(servers, jnp.float32)
    adam_m = jnp.zeros_like(logits)
    adam_v = jnp.zeros_like(logits)

    solver_kw = dict(iters=iters, lr=lr, tol=tol, check_every=check_every,
                     backend=backend, interpret=interpret)
    plan: BatchPlan | None = None
    executes = 0
    history: list[dict] = []
    fleet: list[np.ndarray] = []
    best_ub = np.inf
    best_tm: np.ndarray | None = None
    baseline_search_ub = np.inf

    for r in range(rounds):
        dems = [baseline] + [np.asarray(d) for d in
                             _fleet_project(logits, servers_j, proj_iters)]
        if keep_fleet:
            fleet.extend(np.asarray(d, np.float64) for d in dems[1:])
        if plan is None:
            plan = BatchPlan.build([topo] * candidates, dems,
                                   bucket=bucket, max_lanes=max_lanes,
                                   devices=devices)
        else:
            plan = plan.refill([topo] * candidates, dems)
        solved = plan.execute(solver="dual-demgrad", **solver_kw)
        executes += 1
        ubs = np.asarray([s.value for s in solved])
        baseline_search_ub = min(baseline_search_ub, float(ubs[0]))
        arg = int(ubs.argmin())
        if float(ubs[arg]) < best_ub:
            best_ub = float(ubs[arg])
            best_tm = np.asarray(dems[arg], np.float64)
        history.append({"round": r + 1, "best_ub": best_ub,
                        "round_min_ub": float(ubs.min()),
                        "round_mean_ub": float(ubs.mean()),
                        "baseline_ub": float(ubs[0])})
        if r + 1 == rounds:
            break
        # Adam on the logits along the pulled-back Danskin gradient
        # (descending the log-ratio bound = descending log throughput)
        grads = jnp.asarray(
            np.stack([np.asarray(s.meta["dem_grad"], np.float32)
                      for s in solved[1:]]))
        g = _fleet_pullback(logits, grads, servers_j, proj_iters)
        t = r + 1
        adam_m = 0.9 * adam_m + 0.1 * g
        adam_v = 0.999 * adam_v + 0.001 * g * g
        mh = adam_m / (1 - 0.9 ** t)
        vh = adam_v / (1 - 0.999 ** t)
        logits = logits - lr_tm * mh / (jnp.sqrt(vh) + 1e-8)

    assert best_tm is not None and plan is not None
    # final certification on the SAME plan: lane 0 = argmin TM, lane 1 =
    # baseline, surplus lanes repeat the argmin (identical shapes, so the
    # refill keeps every compile key from round one)
    cert_dems = [best_tm, baseline] + [best_tm] * (candidates - 2)
    certified = plan.refill([topo] * candidates, cert_dems).execute(
        solver="primal", **solver_kw)
    executes += 1
    lb = float(certified[0].value)
    ub = min(best_ub, float(certified[0].meta["ub"]))
    baseline_lb = float(certified[1].value)
    baseline_ub = min(baseline_search_ub, float(certified[1].meta["ub"]))
    stats = {"rounds": rounds, "candidates": candidates,
             "executes": executes, "search_executes": rounds,
             "certify_executes": 1,
             "compile_keys": plan.stats.compile_keys,
             "last_plan": plan.stats.as_dict()}
    return AdversarialResult(
        tm=best_tm, lb=lb, ub=ub, baseline_lb=baseline_lb,
        baseline_ub=baseline_ub,
        uniform_gap_pct=100.0 * (baseline_ub - ub) / max(baseline_ub, 1e-30),
        history=history, stats=stats, fleet=tuple(fleet))
