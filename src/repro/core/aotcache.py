"""Persistent AOT compile cache for the batched solvers.

``plan.BatchPlan`` already deduplicates compiles *within* a process (one
XLA program per (bucket, chunk-shape, solver-config)), but every fresh
process pays the full jit wall again — the fig6-style cold-start tax.
This module serializes compiled executables to disk so a warm process
skips XLA entirely:

* ``AotCache(dir).call(jitfn, tag, args, static_kw)`` — look up the
  executable keyed by (jax version, backend, device kind/count, tag,
  arg shapes/dtypes, static kwargs).  On a hit the serialized executable
  is deserialized and invoked; on a miss the function is lowered +
  compiled ahead-of-time, serialized to the cache directory, then
  invoked.  ANY failure (stale jax, incompatible device, corrupt blob)
  falls back to the plain jitted call — the cache can only make things
  faster, never wrong.
* ``resolve(knob)`` — map an engine-level knob (None / bool / directory
  path) to an ``AotCache`` or ``None``.  ``None`` defers to the
  ``REPRO_AOT_CACHE`` env var (truthy enables; ``REPRO_AOT_CACHE_DIR``
  overrides the location), so CI can flip the cache on without touching
  call sites.
* module-level counters (``stats()``) — ``compiles`` / ``hits`` /
  ``misses`` / ``errors``, surfaced through
  ``plan.compile_cache_sizes()`` so benchmark drivers can assert the
  zero-new-compiles warm-run invariant.

Single-device only: sharded executables bake in device assignments that
do not survive serialization portably, so the engines gate ``aot`` calls
on ``sharding is None``.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import warnings
from pathlib import Path
from typing import Any, Mapping, Sequence

import jax

__all__ = ["AotCache", "resolve", "default_dir", "stats", "reset_stats"]

_COUNTERS = {"compiles": 0, "hits": 0, "misses": 0, "errors": 0}
_WARNED: set[str] = set()


def stats() -> dict[str, int]:
    """Process-wide cache counters (copies; see module docstring)."""
    return dict(_COUNTERS)


def reset_stats() -> None:
    for k in _COUNTERS:
        _COUNTERS[k] = 0


def default_dir() -> Path:
    env = os.environ.get("REPRO_AOT_CACHE_DIR")
    if env:
        return Path(env).expanduser()
    return Path("~/.cache/repro/aot").expanduser()


def resolve(knob: bool | str | os.PathLike | None) -> "AotCache | None":
    """Map the engine's ``aot_cache`` knob to a cache instance.

    ``None`` -> env-controlled (``REPRO_AOT_CACHE`` truthy enables),
    ``False`` -> off, ``True`` -> default directory, str/path -> that
    directory."""
    if knob is None:
        env = os.environ.get("REPRO_AOT_CACHE", "").strip().lower()
        if env in ("", "0", "false", "off", "no"):
            return None
        knob = True
    if knob is False:
        return None
    if knob is True:
        return AotCache(default_dir())
    return AotCache(Path(knob).expanduser())


def _warn_once(key: str, msg: str) -> None:
    if key not in _WARNED:
        _WARNED.add(key)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _abstract(x: Any) -> tuple:
    a = jax.api_util.shaped_abstractify(x)
    return (tuple(a.shape), str(a.dtype))


class AotCache:
    """Directory-backed store of serialized compiled executables."""

    def __init__(self, directory: os.PathLike | str):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    # -- keying ---------------------------------------------------------
    def _key(self, tag: Sequence[str], args: Sequence[Any],
             static_kw: Mapping[str, Any]) -> str:
        devs = jax.devices()
        fp = repr((
            jax.__version__,
            jax.default_backend(),
            devs[0].device_kind if devs else "none",
            len(devs),
            tuple(tag),
            tuple(_abstract(a) for a in args),
            tuple(sorted((k, repr(v)) for k, v in static_kw.items())),
        ))
        return hashlib.sha256(fp.encode()).hexdigest()[:32]

    def _path(self, key: str) -> Path:
        return self.dir / f"{key}.aot"

    # -- core -----------------------------------------------------------
    def call(self, jitfn: Any, tag: Sequence[str], args: Sequence[Any],
             static_kw: Mapping[str, Any]) -> Any:
        """Run ``jitfn(*args, **static_kw)`` through the cache.

        Hit: deserialize the stored executable and invoke it on ``args``.
        Miss: ``jitfn.lower(...).compile()``, serialize, store, invoke.
        Any error: warn once and fall back to the plain jitted call."""
        try:
            from jax.experimental import serialize_executable as se
        except Exception:  # pragma: no cover - jax always ships it today
            _warn_once("import", "aotcache: serialize_executable "
                       "unavailable; AOT cache disabled")
            _COUNTERS["errors"] += 1
            return jitfn(*args, **static_kw)

        try:
            key = self._key(tag, args, static_kw)
            path = self._path(key)
        except Exception as e:
            _COUNTERS["errors"] += 1
            _warn_once("key", f"aotcache: keying failed ({e!r}); "
                       "falling back to jit")
            return jitfn(*args, **static_kw)

        if path.exists():
            try:
                blob = pickle.loads(path.read_bytes())
                compiled = se.deserialize_and_load(
                    blob["payload"], blob["in_tree"], blob["out_tree"])
                out = compiled(*args)
                _COUNTERS["hits"] += 1
                return out
            except Exception as e:
                _COUNTERS["errors"] += 1
                _warn_once(f"load:{key}",
                           f"aotcache: stale/corrupt entry {path.name} "
                           f"({e!r}); recompiling")
                try:
                    path.unlink()
                except OSError:
                    pass

        _COUNTERS["misses"] += 1
        try:
            compiled = jitfn.lower(*args, **static_kw).compile()
            payload, in_tree, out_tree = se.serialize(compiled)
            _COUNTERS["compiles"] += 1
            tmp = path.with_suffix(f".tmp{os.getpid()}")
            tmp.write_bytes(pickle.dumps(
                {"payload": payload, "in_tree": in_tree,
                 "out_tree": out_tree, "meta": {"tag": tuple(tag)}}))
            os.replace(tmp, path)
            return compiled(*args)
        except Exception as e:
            _COUNTERS["errors"] += 1
            _warn_once(f"compile:{'/'.join(map(str, tag))}",
                       f"aotcache: AOT path failed ({e!r}); "
                       "falling back to jit")
            return jitfn(*args, **static_kw)

    def entries(self) -> list[str]:
        return sorted(p.stem for p in self.dir.glob("*.aot"))
