"""Throughput decomposition T = C·U / (f·⟨D⟩·AS)   (paper §6.1, Fig. 8).

The paper writes T = C·U/(⟨D⟩·AS) with the flow count f absorbed into the
normalisation; we keep f explicit so the identity holds exactly:

    Σ_e flow_e  =  U·C            (definition of capacity-weighted utilisation)
    Σ_e flow_e  =  Σ_i x_i·len_i  (flow decomposition; len_i = avg routed hops)
                =  θ·f·⟨D⟩·AS     (concurrent flow: x_i = θ·dem_i; AS = stretch)

    ⇒  θ = C·U / (f·⟨D⟩·AS)

Also provides the per-link-class utilisation breakdown the paper uses to
locate bottlenecks (intra-small / intra-large / cross-cluster).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import lp as _lp
from repro.core.graphs import Topology

__all__ = ["Decomposition", "decompose", "utilization_by_class"]


@dataclasses.dataclass(frozen=True)
class Decomposition:
    throughput: float     # θ (per unit-demand concurrent rate)
    capacity: float       # C: total capacity, both directions
    utilization: float    # U: Σ flow / Σ cap
    aspl: float           # ⟨D⟩: demand-weighted shortest path length (hops)
    stretch: float        # AS: flow-weighted routed hops / ⟨D⟩
    flows: float          # f: Σ dem

    @property
    def reconstructed(self) -> float:
        """C·U/(f·⟨D⟩·AS) — must equal ``throughput`` up to LP tolerance."""
        return self.capacity * self.utilization / (
            self.flows * self.aspl * self.stretch)


def decompose(cap: Topology | np.ndarray, dem: np.ndarray,
              result: _lp.FlowResult | None = None) -> Decomposition:
    """Decompose the throughput of (cap, dem) into the paper's four factors."""
    if result is None:
        result = _lp.max_concurrent_flow(cap, dem, want_flows=True)
    theta = result.throughput
    c = float(result.edge_cap.sum())
    total_flow = float(result.edge_flow.sum())
    u = total_flow / c
    aspl = _lp.aspl_hops(cap, dem)
    f = float(dem.sum())
    delivered = theta * f
    routed_hops = total_flow / delivered if delivered > 0 else float("nan")
    stretch = routed_hops / aspl if aspl > 0 else float("nan")
    return Decomposition(throughput=theta, capacity=c, utilization=u,
                         aspl=aspl, stretch=stretch, flows=f)


def utilization_by_class(result: _lp.FlowResult,
                         labels: np.ndarray) -> dict[tuple[int, int], float]:
    """Average link utilisation per (label_u, label_v) edge class, with the
    class key sorted so (0,1) covers both directions of cross-cluster links."""
    labels = np.asarray(labels)
    out: dict[tuple[int, int], list] = {}
    for (u, v), c, f in zip(result.edges, result.edge_cap, result.edge_flow):
        key = tuple(sorted((int(labels[u]), int(labels[v]))))
        out.setdefault(key, []).append(f / c if c > 0 else 0.0)
    return {k: float(np.mean(v)) for k, v in out.items()}
