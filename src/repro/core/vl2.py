"""VL2 and its degree-proportional random rewiring (paper §7, Fig. 11).

VL2 [Greenberg et al., SIGCOMM'09]: ToRs with 20 x 1GbE servers and 2 x 10GbE
uplinks to two aggregation switches; full bipartite 10GbE mesh between
aggregation (D_A ports) and core/intermediate (D_I ports) switches.  Such a
VL2 supports D_A*D_I/4 ToRs at full throughput by construction.

The paper's rewiring keeps every piece of equipment (same ToRs, same agg,
same core switches) but (a) spreads ToR uplinks over agg AND core switches in
proportion to their port counts and (b) wires all remaining agg/core ports as
a uniform random graph.  Capacity units: 1 = 1GbE, so fabric links are 10.

Throughput checks run through ``repro.core.engine``: the ``engine`` argument
of the drivers accepts a registry name ("exact", "dual", ...) or a
``ThroughputEngine`` instance, and batching engines check all seeded runs of
a candidate topology in one ``solve_batch`` call.

Beyond the hand-coded recipe, ``designed_vl2_topology`` runs the fleet
optimizer (``repro.design``) over the same equipment and plugs into
``max_tors_at_full_throughput`` as a drop-in ``build_fn`` — Fig. 11 reports
hand-rewired vs optimizer-found gains side by side.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import engine as engine_mod
from repro.core import graphs, traffic

__all__ = [
    "VL2Spec", "vl2_topology", "rewired_vl2_topology",
    "designed_vl2_topology", "supports_full_throughput",
    "max_tors_at_full_throughput",
]

FABRIC = 10.0   # 10GbE in units of 1GbE


@dataclasses.dataclass(frozen=True)
class VL2Spec:
    d_a: int                    # ports per aggregation switch (10G)
    d_i: int                    # ports per core/intermediate switch (10G)
    servers_per_tor: int = 20

    @property
    def n_agg(self) -> int:
        return self.d_i            # full bipartite: core degree = #agg

    @property
    def n_core(self) -> int:
        return self.d_a // 2       # agg splits ports half down / half up

    @property
    def n_tor_full(self) -> int:
        return self.d_a * self.d_i // 4


def vl2_topology(spec: VL2Spec, n_tor: int | None = None,
                 server_nodes: bool = False) -> graphs.Topology:
    """The stock VL2 topology.  Node order: [ToRs | aggs | cores]; labels
    0=ToR, 1=agg, 2=core.  ``server_nodes=True`` returns the server-
    expanded view (each server its own degree-1 leaf on a 1GbE NIC link);
    the planning engines contract it back onto this ToR-level graph by
    default (``Topology.coarsen`` — exact, smaller padded lanes)."""
    n_tor = spec.n_tor_full if n_tor is None else n_tor
    if n_tor > spec.n_tor_full:
        raise ValueError("VL2 wiring cannot host more than D_A*D_I/4 ToRs")
    na, nc = spec.n_agg, spec.n_core
    n = n_tor + na + nc
    cap = np.zeros((n, n))
    agg0, core0 = n_tor, n_tor + na
    # ToR i: two uplinks to distinct aggs, assigned round-robin; with a
    # single agg (na == 1) both uplinks land on it, doubling that capacity
    for i in range(n_tor):
        a1 = (2 * i) % na
        a2 = (2 * i + 1) % na
        cap[i, agg0 + a1] += FABRIC
        cap[agg0 + a1, i] += FABRIC
        cap[i, agg0 + a2] += FABRIC
        cap[agg0 + a2, i] += FABRIC
    # full bipartite agg <-> core
    for a in range(na):
        for c in range(nc):
            cap[agg0 + a, core0 + c] += FABRIC
            cap[core0 + c, agg0 + a] += FABRIC
    servers = np.concatenate([np.full(n_tor, spec.servers_per_tor, np.int64),
                              np.zeros(na + nc, np.int64)])
    labels = np.concatenate([np.zeros(n_tor, np.int64),
                             np.ones(na, np.int64),
                             np.full(nc, 2, np.int64)])
    topo = graphs.Topology(cap=cap, servers=servers, labels=labels)
    return topo.with_server_nodes() if server_nodes else topo


def rewired_vl2_topology(spec: VL2Spec, n_tor: int, seed: int,
                         server_nodes: bool = False) -> graphs.Topology:
    """Same equipment as ``vl2_topology`` but rewired per the paper:
    ToR uplinks spread over agg+core in proportion to port count; all
    remaining agg/core ports wired uniformly at random (all links 10G).
    ``server_nodes`` as in ``vl2_topology``."""
    na, nc = spec.n_agg, spec.n_core
    n = n_tor + na + nc
    agg0, core0 = n_tor, n_tor + na
    rng = np.random.default_rng(seed)

    # --- distribute the 2*n_tor ToR uplinks over agg/core by port count ----
    uplinks = 2 * n_tor
    ports = np.concatenate([np.full(na, spec.d_a), np.full(nc, spec.d_i)])
    total_ports = int(ports.sum())
    if uplinks > total_ports:
        raise ValueError("not enough fabric ports for the ToR uplinks")
    ideal = uplinks * ports / total_ports
    take = np.floor(ideal).astype(np.int64)
    rem = uplinks - int(take.sum())
    if rem > 0:
        take[np.argsort(-(ideal - take))[:rem]] += 1
    take = np.minimum(take, ports)      # safety; ports >> take in practice

    cap = np.zeros((n, n))
    # round-robin the ToR uplink endpoints over the per-switch quotas so each
    # ToR's two uplinks land on different switches whenever possible
    endpoints = np.repeat(np.arange(na + nc), take)
    endpoints = rng.permutation(endpoints)
    for i in range(n_tor):
        e1, e2 = endpoints[2 * i], endpoints[2 * i + 1]
        if e1 == e2:
            alt = np.flatnonzero(endpoints != e1)
            if len(alt):
                j = int(alt[rng.integers(len(alt))])
                endpoints[2 * i + 1], endpoints[j] = endpoints[j], endpoints[2 * i + 1]
                e2 = endpoints[2 * i + 1]
        for e in (e1, e2):
            u = agg0 + int(e)
            cap[i, u] += FABRIC
            cap[u, i] += FABRIC

    # --- random graph over the remaining agg/core ports --------------------
    used = np.bincount(endpoints, minlength=na + nc)
    deg = ports - used
    if deg.sum() % 2 != 0:
        deg[int(np.argmax(deg))] -= 1
    sub = graphs._random_graph_cap(deg, seed + 1, capacity=FABRIC)
    cap[agg0:, agg0:] += sub

    servers = np.concatenate([np.full(n_tor, spec.servers_per_tor, np.int64),
                              np.zeros(na + nc, np.int64)])
    labels = np.concatenate([np.zeros(n_tor, np.int64),
                             np.ones(na, np.int64),
                             np.full(nc, 2, np.int64)])
    topo = graphs.Topology(cap=cap, servers=servers, labels=labels)
    return topo.with_server_nodes() if server_nodes else topo


def designed_vl2_topology(spec: VL2Spec, n_tor: int, seed: int, *,
                          rounds: int = 2, fleet: int = 6, runs: int = 2,
                          engine=None, traffic_fn=None,
                          server_nodes: bool = False) -> graphs.Topology:
    """Optimizer-found wiring of the same VL2 equipment: a fleet search
    (``repro.design.optimize`` over ``VL2Space``) seeded from the paper's
    proportional rewiring, using degree-preserving double-edge swaps on the
    10GbE links (ToR–ToR links stay forbidden).  Because the recipe wiring
    is candidate 0 and the final selection maximises the certified lower
    bound over elites AND that reference, the returned topology is never
    certified worse than ``rewired_vl2_topology`` on the same traffic.

    The ``(spec, n_tor, seed)`` signature matches the ``build_fn`` slot of
    ``max_tors_at_full_throughput``, so Fig. 11 can binary-search the
    designed wiring exactly like the hand-coded one.  ``engine`` must be a
    planning engine (default: the designer's cheap-ranking dual engine);
    ``traffic_fn(servers, seed)`` overrides the random-permutation samples
    the search scores candidates on.
    """
    from repro.design import VL2Space, optimize

    demand_fn = None if traffic_fn is None else \
        (lambda topo, s: traffic_fn(topo.servers, s))
    result = optimize(VL2Space(spec, n_tor), demand_fn=demand_fn,
                      engine=engine, moves=("swap",), rounds=rounds,
                      fleet=fleet, runs=runs, seed=seed)
    topo = result.best.cand.topo
    return topo.with_server_nodes() if server_nodes else topo


def _criterion_value(result) -> float:
    """The throughput figure a pass/fail criterion should judge: the
    certified LOWER bound when the engine reports a bracket (so "supports
    full throughput" is a certified claim, not an optimistic upper-bound
    one), else the result's headline throughput."""
    return result.meta.get("lb", result.throughput)


def supports_full_throughput(topo: graphs.Topology, runs: int, seed0: int,
                             engine="exact", tol: float = 1e-6,
                             traffic_fn=None) -> bool:
    """Paper's criterion: >= 1 unit (1 Gbps) for every flow of a random
    permutation (or ``traffic_fn(servers, seed)``), across all runs.

    On a bracket engine (``get_engine("certified")``) the test uses each
    run's certified lower bound, so a True answer is a proof, not an
    upper-bound estimate.
    """
    eng = engine_mod.as_engine(engine)
    dems = [(traffic.random_permutation(topo.servers, seed0 + rr)
             if traffic_fn is None else traffic_fn(topo.servers, seed0 + rr))
            for rr in range(runs)]
    if eng.batches:
        results = eng.solve_batch([topo] * runs, dems)
        return all(_criterion_value(r) >= 1.0 - tol for r in results)
    for dem in dems:       # sequential engine: keep the early exit
        if _criterion_value(eng.solve(topo, dem)) < 1.0 - tol:
            return False
    return True


def max_tors_at_full_throughput(spec: VL2Spec, build_fn, lo: int, hi: int,
                                runs: int = 3, seed0: int = 0,
                                engine="exact",
                                traffic_fn=None) -> int:
    """Binary search the largest n_tor with full throughput (paper Fig. 11).
    ``build_fn(spec, n_tor, seed) -> Topology`` — ``vl2_topology`` (stock),
    ``rewired_vl2_topology`` (paper recipe), or ``designed_vl2_topology``
    (fleet-optimizer wiring) all fit the slot."""
    def ok(n_tor: int) -> bool:
        if n_tor <= 0:
            return True
        try:
            topo = build_fn(spec, n_tor, seed0)
        except ValueError:
            return False      # not physically wirable -> not supported
        return supports_full_throughput(topo, runs, seed0 + 17, engine,
                                        traffic_fn=traffic_fn)

    while not ok(lo):
        hi = lo
        lo = lo // 2
        if lo == 0:
            raise ValueError("even 1 ToR is infeasible")
    while ok(hi):
        lo, hi = hi, hi * 2
        if hi > 4096:
            break
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if ok(mid):
            lo = mid
        else:
            hi = mid
    return lo
