"""APSP backend registry (``ApspBackend``) and the shared SP-DAG
subgradient seam.

One public entry point, ``apsp(w, backend, interpret, d_max, max_rounds)``,
closes an (N, N) weight matrix over the tropical semiring.  The forward
pass dispatches on the backend registry:

* ``"squaring"``        — pure-jnp repeated (min,+) squaring (the legacy
  default path; ``O(N^3 log N)`` work, ``O(N^3)`` broadcast per step);
* ``"squaring-pallas"`` — repeated squaring on the Pallas tropical-matmul
  kernel (what ``use_pallas=True`` historically selected);
* ``"blocked-fw"``      — blocked Floyd-Warshall (``repro.kernels.fw``):
  one ``O(N^3)`` pass, ``O(N^2)`` live memory.  Compiled Pallas tiles on
  TPU (or with explicit ``interpret=True``); a ``lax.fori`` Floyd-Warshall
  on CPU where the interpreter would be the bottleneck;
* ``"ell-bf"``          — sparse-frontier Bellman-Ford relaxation over a
  fixed-width padded-ELL neighbor table (``repro.kernels.ell``).  The
  caller supplies the static table width ``d_max`` (>= the graph's max
  degree); work per round is ``O(N^2 d_max)`` and rounds stop at the
  diameter, so degree-bounded graphs close in a fraction of any dense
  pass.  Padded-ELL keeps every shape static: the backend jits, vmaps
  over solver lanes, and keys into the AOT cache like the dense ones;
* ``"auto"``            — ``"blocked-fw"`` for ``n >= AUTO_THRESHOLD``
  else ``"squaring"`` (a static shape decision, so it is jit-safe).
  When the caller can supply density information, ``resolve_backend``
  upgrades large sparse instances to ``"ell-bf"``: ``mean_degree <=
  SPARSE_THRESHOLD`` and ``n >= AUTO_THRESHOLD``.  A bare ``apsp(w,
  "auto")`` never goes sparse implicitly — density is a host-side fact
  the solvers compute from capacity patterns (``graphs.degree_stats``).

``normalize_backend`` maps the legacy ``use_pallas`` booleans threaded
through ``mcf``/``primal``/``engine`` onto registry names, so existing
call sites (``get_engine("dual-pallas")``, ``use_pallas=True``) keep
working unchanged.

**The subgradient seam.**  All backends share ONE ``jax.custom_vjp``
backward: a Bellman fixed-point adjoint that only needs the saved
``(w, D)`` pair.  At the fixed point ``D[s,t] = min_{k != t} D[s,k] +
w[k,t]`` (the diagonal is excluded so no cotangent leaks into the fixed
zero diagonal), so the backward peels one hop off the end of every
shortest path per sweep: the tie-split predecessor mask (relative
tolerance from PR 4) routes each pair's cotangent one edge back along
the SP-DAG, depositing the edge's share of ``dw`` as it goes, until the
mass drains onto the diagonal (path complete).  Consequences:

* subgradients are **identical across backends by construction** — the
  backward never sees which forward produced ``D``.  The ``"ell-bf"``
  backend routes the same walk through ``_sp_dag_grad_ell``, which
  enumerates predecessors from the ELL table (``O(N^2 d_max)`` per
  sweep) instead of walking dense N-chunks — the tie masks, counts, and
  routed masses are the same quantities, element for element;
* per-pair gradient mass is a unit flow routed on shortest paths (what
  the Frank-Wolfe primal oracle requires);
* backward memory is ``O(N^2 * chunk)`` (t-chunked mask slabs) instead
  of the ``O(N^3)`` tie-mask of the per-matmul VJP, and backward work is
  ``O(diameter * N^3 / chunk-parallelism)`` — diameters of the graphs
  here are small.  Chunks whose cotangent has fully drained (and padded
  lanes, which never carry mass) are skipped by a ``lax.cond`` instead
  of relaxing all-``_INF`` rows.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ell as kell
from repro.kernels import fw as kfw
from repro.kernels import ops as kops

__all__ = ["apsp", "normalize_backend", "resolve_backend", "BACKENDS",
           "AUTO_THRESHOLD", "SPARSE_THRESHOLD", "_INF"]

_INF = 1.0e18   # non-edge sentinel: survives one add in float32 headroom

BACKENDS = ("squaring", "squaring-pallas", "blocked-fw", "ell-bf", "auto")
AUTO_THRESHOLD = 512     # auto: blocked-fw at and above this padded size
SPARSE_THRESHOLD = 32.0  # auto: ell-bf when mean degree is at most this
_FW_TILE = 128           # Pallas tile for the blocked-fw flavor
_BWD_ELEMS = 1 << 25     # float budget for one backward mask slab


def normalize_backend(backend: str | bool | None = None,
                      use_pallas: bool = False) -> str:
    """Map a backend spec (registry name, legacy ``use_pallas`` bool, or
    None) to a registry name.  ``None`` defers to ``use_pallas`` for
    compatibility: True -> "squaring-pallas", False -> "auto"."""
    if backend is None:
        return "squaring-pallas" if use_pallas else "auto"
    if isinstance(backend, bool):   # legacy positional use_pallas slot
        return "squaring-pallas" if backend else "squaring"
    if backend not in BACKENDS:
        raise ValueError(f"unknown APSP backend {backend!r}; "
                         f"known: {BACKENDS}")
    return backend


def resolve_backend(backend: str, n: int, *,
                    mean_degree: float | None = None) -> str:
    """Resolve "auto" against a concrete (static) matrix size, and — when
    the caller knows it — the graph's mean degree.  Density is optional
    and host-side: without it the choice is the dense PR 7 ladder; with
    it, large degree-bounded instances resolve to ``"ell-bf"``."""
    backend = normalize_backend(backend)
    if backend == "auto":
        if (mean_degree is not None and n >= AUTO_THRESHOLD
                and mean_degree <= SPARSE_THRESHOLD):
            return "ell-bf"
        return "blocked-fw" if n >= AUTO_THRESHOLD else "squaring"
    return backend


def _squaring_steps(n: int) -> int:
    return max(1, math.ceil(math.log2(max(n - 1, 2))))


def _clamp_d_max(d_max: int, n: int) -> int:
    return max(1, min(int(d_max), max(n - 1, 1)))


def _pack_ell(w: jax.Array, d_max: int) -> tuple[jax.Array, jax.Array]:
    """Pack a dense weight matrix into incoming padded-ELL tables: row
    ``t`` of ``(idx, wgt)`` lists the predecessors ``k`` with ``wgt[t, j]
    = w[idx[t, j], t]``, sorted ascending, pads LAST with ``idx = t`` /
    ``wgt = _INF`` (the convention ``repro.kernels.ell`` relaxes and
    ``Topology.to_ell`` exports).  Traceable, so the solvers can pack
    under jit/vmap; ``d_max`` must cover the max in-degree — rows with
    more finite entries than ``d_max`` would be silently truncated, so
    host layers validate it (``graphs.degree_stats``)."""
    n = w.shape[-1]
    d_max = _clamp_d_max(d_max, n)
    rows = jnp.arange(n)
    # wt[t, k] = w[k, t]; the diagonal is masked so the zero self-entry
    # never competes with real edges for a table slot
    wt = jnp.where(rows[:, None] == rows[None, :], _INF,
                   jnp.swapaxes(w, -1, -2).astype(jnp.float32))
    neg, cols = jax.lax.top_k(-wt, d_max)     # d_max smallest per row
    vals = -neg
    valid = vals < _INF / 2
    order = jnp.argsort(jnp.where(valid, cols, n), axis=-1)  # pads last
    idx = jnp.take_along_axis(jnp.where(valid, cols, rows[:, None]),
                              order, axis=-1).astype(jnp.int32)
    wgt = jnp.take_along_axis(jnp.where(valid, vals, _INF), order, axis=-1)
    return idx, wgt


def _apsp_forward(w: jax.Array, backend: str, interpret: bool | None,
                  d_max: int | None = None, max_rounds: int | None = None):
    n = w.shape[0]
    kind = resolve_backend(backend, n)
    d = w.astype(jnp.float32)
    if kind == "ell-bf":
        if d_max is None:
            raise ValueError("ell-bf needs a static d_max (max degree of "
                             "the packed table); compute it host-side, "
                             "e.g. graphs.degree_stats(cap)")
        idx, wgt = _pack_ell(d, d_max)
        # same flavor split as blocked-fw below: the solvers pre-resolve
        # interpret=None to True on CPU, so only the platform can pick
        # the Pallas round here; tests drive it via kernels.ell directly
        dd, _ = kell.ell_bf_apsp_impl(
            idx, wgt, max_rounds=max_rounds,
            use_pallas=jax.default_backend() == "tpu", interpret=interpret)
        return dd
    if kind == "blocked-fw":
        # the tiled Pallas kernel only pays off compiled (TPU); elsewhere
        # the lax.fori Floyd-Warshall is the fast flavor (the solvers
        # pre-resolve interpret=None to True on CPU, so an interpret bool
        # cannot distinguish "explicitly requested interpreter" here —
        # tests drive the 4-phase interpret path via kernels.fw directly)
        if jax.default_backend() != "tpu":
            return kfw.fw_apsp_jnp(d)
        pad = (-n) % _FW_TILE
        if pad:
            d = jnp.pad(d, ((0, pad), (0, pad)), constant_values=_INF)
        d = kfw.fw_apsp_pallas(d, t=_FW_TILE, interpret=interpret)
        return d[:n, :n] if pad else d
    for _ in range(_squaring_steps(n)):
        if kind == "squaring-pallas":
            d = jnp.minimum(d, kops.minplus_matmul(d, d, 128, interpret))
        else:
            d = jnp.minimum(d, jnp.min(d[:, :, None] + d[None, :, :],
                                       axis=1))
    return d


def _bwd_chunk(n: int, d_max: int | None = None) -> int:
    per_target = n * (d_max if d_max is not None else n)
    return max(1, min(n, _BWD_ELEMS // max(per_target, 1)))


def _sp_dag_grad(w: jax.Array, d: jax.Array, g: jax.Array) -> jax.Array:
    """Backward of the APSP closure: route the cotangent ``g`` on ``D``
    back along the shortest-path DAG of ``(w, D)``, one hop per sweep."""
    n = w.shape[0]
    eye = jnp.eye(n, dtype=bool)
    reach = d < _INF / 2
    # no gradient through the fixed zero diagonal or unreachable pairs
    # (D is locally constant at the sentinel there)
    u0 = jnp.where(reach & ~eye, g, 0.0).astype(jnp.float32)
    c = _bwd_chunk(n)
    pad = (-n) % c
    wf = w.astype(jnp.float32)
    df = d.astype(jnp.float32)
    if pad:
        wf = jnp.pad(wf, ((0, pad), (0, pad)), constant_values=_INF)
        df = jnp.pad(df, ((0, pad), (0, pad)), constant_values=_INF)
        u0 = jnp.pad(u0, ((0, pad), (0, pad)))
    m = n + pad
    eye_m = jnp.eye(m, dtype=bool)
    kidx = jnp.arange(m)

    def one_hop(u, dw):
        def chunk_body(j, acc):
            t0 = j * c
            uc = jax.lax.dynamic_slice_in_dim(u, t0, c, axis=1)

            def relax(acc):
                un, dwn = acc
                wc = jax.lax.dynamic_slice_in_dim(wf, t0, c, axis=1)
                dc = jax.lax.dynamic_slice_in_dim(df, t0, c, axis=1)
                s = df[:, :, None] + wc[None, :, :]               # (m, m, c)
                # relative tie tolerance (PR 4): edge lengths span many
                # orders of magnitude under the dual's log-length ascent
                tol = 1e-6 * jnp.maximum(jnp.abs(dc), 1e-6)
                mask = s <= (dc + tol)[:, None, :]
                # k == t would tie via the zero diagonal every sweep and
                # stall the drain; the fixed point excludes it
                mask &= (kidx[None, :, None]
                         != (t0 + jnp.arange(c))[None, None, :])
                mf = mask.astype(jnp.float32)
                mf = mf / jnp.maximum(mf.sum(axis=1, keepdims=True), 1.0)
                mf = mf * uc[:, None, :]
                # cotangent one hop back, accumulated STRICTLY in
                # ascending-target order: left-to-right float addition is
                # chunking-invariant, which is what lets the ELL-aware
                # adjoint (different chunk widths) stay bit-identical
                un = jax.lax.fori_loop(
                    0, c,
                    lambda tc, acc: acc + jax.lax.dynamic_index_in_dim(
                        mf, tc, axis=2, keepdims=False),
                    un)
                dep = jax.lax.dynamic_slice_in_dim(dwn, t0, c, axis=1)
                dwn = jax.lax.dynamic_update_slice_in_dim(
                    dwn, dep + mf.sum(axis=0), t0, axis=1)
                return un, dwn

            # a drained chunk — and every fully-padded all-_INF chunk,
            # whose lanes can never carry mass — routes zeros; skip the
            # O(m^2 c) slab instead of relaxing it (exact: the slab with
            # uc == 0 adds +0.0 everywhere, so bits are unchanged)
            return jax.lax.cond(jnp.any(uc != 0.0), relax,
                                lambda acc: acc, acc)

        return jax.lax.fori_loop(0, m // c, chunk_body,
                                 (jnp.zeros_like(u), dw))

    def cond(carry):
        u, _, it = carry
        return (it < m) & (jnp.max(jnp.abs(u)) > 0.0)

    def body(carry):
        u, dw, it = carry
        u2, dw2 = one_hop(u, dw)
        # mass arriving on the diagonal is a completed path
        return jnp.where(eye_m, 0.0, u2), dw2, it + 1

    _, dw, _ = jax.lax.while_loop(cond, body,
                                  (u0, jnp.zeros_like(wf), 0))
    if pad:
        dw = dw[:n, :n]
    return dw.astype(w.dtype)


def _sp_dag_grad_ell(w: jax.Array, d: jax.Array, g: jax.Array,
                     d_max: int) -> jax.Array:
    """ELL-aware flavor of :func:`_sp_dag_grad`: the one-hop walk
    enumerates each target's predecessors from the incoming ELL table
    (``d_max`` slots) instead of scanning all N candidates, so a sweep
    is ``O(N^2 d_max)`` work and the mask slab is ``(N, chunk, d_max)``.
    Same tie masks, same counts, same routed masses — the table rows ARE
    the finite column entries of ``w``, ascending, and pads carry
    ``_INF`` so they never tie."""
    n = w.shape[0]
    d_max = _clamp_d_max(d_max, n)
    idx, wgt = _pack_ell(w, d_max)      # idx[t, j] = k, wgt[t, j] = w[k, t]
    eye = jnp.eye(n, dtype=bool)
    reach = d < _INF / 2
    u0 = jnp.where(reach & ~eye, g, 0.0).astype(jnp.float32)
    df = d.astype(jnp.float32)
    c = _bwd_chunk(n, d_max)
    pad = (-n) % c
    if pad:
        # pad the TARGET axis only (predecessors stay the n real rows):
        # padded rows get idx 0 / wgt _INF, so they tie nowhere and
        # scatter +0.0 onto column 0
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        wgt = jnp.pad(wgt, ((0, pad), (0, 0)), constant_values=_INF)
        df_t = jnp.pad(df, ((0, 0), (0, pad)), constant_values=_INF)
        u0 = jnp.pad(u0, ((0, 0), (0, pad)))
    else:
        df_t = df
    m = n + pad
    diag = jnp.arange(n)[:, None] == jnp.arange(m)[None, :]

    def one_hop(u, dw_ell):
        def chunk_body(j, acc):
            t0 = j * c
            uc = jax.lax.dynamic_slice_in_dim(u, t0, c, axis=1)

            def relax(acc):
                un, dwn = acc
                ic = jax.lax.dynamic_slice_in_dim(idx, t0, c, axis=0)
                wc = jax.lax.dynamic_slice_in_dim(wgt, t0, c, axis=0)
                dc = jax.lax.dynamic_slice_in_dim(df_t, t0, c, axis=1)
                # dk[s, tc, j] = D[s, idx[t0 + tc, j]]
                dk = jnp.take(df, ic.reshape(-1), axis=1,
                              ).reshape(n, c, d_max)
                s = dk + wc[None, :, :]                    # (n, c, d_max)
                tol = 1e-6 * jnp.maximum(jnp.abs(dc), 1e-6)
                # pads carry _INF and the diagonal is never packed, so
                # non-edges and k == t are excluded by construction
                mask = (s <= (dc + tol)[:, :, None]) & (wc < _INF / 2)[None]
                mf = mask.astype(jnp.float32)
                mf = mf / jnp.maximum(mf.sum(axis=2, keepdims=True), 1.0)
                mf = mf * uc[:, :, None]
                # cotangent one hop back, one ascending target at a time
                # (mirrors the dense adjoint's accumulation order so the
                # two stay bit-identical; within one target each real k
                # holds exactly one slot, and pad slots add exact +0.0)
                un = jax.lax.fori_loop(
                    0, c,
                    lambda tc, acc: acc.at[
                        :, jax.lax.dynamic_index_in_dim(
                            ic, tc, axis=0, keepdims=False)].add(
                        jax.lax.dynamic_index_in_dim(
                            mf, tc, axis=1, keepdims=False)),
                    un)
                dep = jax.lax.dynamic_slice_in_dim(dwn, t0, c, axis=0)
                dwn = jax.lax.dynamic_update_slice_in_dim(
                    dwn, dep + mf.sum(axis=0), t0, axis=0)
                return un, dwn

            return jax.lax.cond(jnp.any(uc != 0.0), relax,
                                lambda acc: acc, acc)

        return jax.lax.fori_loop(0, m // c, chunk_body,
                                 (jnp.zeros_like(u), dw_ell))

    def cond(carry):
        u, _, it = carry
        return (it < m) & (jnp.max(jnp.abs(u)) > 0.0)

    def body(carry):
        u, dw_ell, it = carry
        u2, dw2 = one_hop(u, dw_ell)
        return jnp.where(diag, 0.0, u2), dw2, it + 1

    _, dw_ell, _ = jax.lax.while_loop(
        cond, body, (u0, jnp.zeros((m, d_max), jnp.float32), 0))
    # deposits live in ELL layout dw_ell[t, j]; one scatter lands them on
    # the dense edge (k = idx[t, j], t).  Pads add +0.0 to the diagonal.
    dw = jnp.zeros((n, n), jnp.float32)
    dw = dw.at[idx[:n], jnp.arange(n)[:, None]].add(dw_ell[:n])
    return dw.astype(w.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def apsp(w: jax.Array, backend: str = "auto",
         interpret: bool | None = None, d_max: int | None = None,
         max_rounds: int | None = None) -> jax.Array:
    """All-pairs shortest path lengths of a dense weighted digraph.

    ``w``: (N, N) edge lengths, zero diagonal, ``_INF`` for non-edges
    (positive lengths; zero-length cycles make the subgradient tie-split
    ill-defined).  ``backend`` is an ``ApspBackend`` registry name (see
    module docstring); ``interpret`` is the Pallas escape hatch threaded
    to the kernels.  ``d_max`` (static, required by ``"ell-bf"``) is the
    padded-ELL table width — at least the graph's max degree — and
    ``max_rounds`` (static, optional) caps the relaxation rounds, default
    N; both are compile-key material.  Differentiable on every backend
    via the shared fixed-point adjoint."""
    return _apsp_forward(w, normalize_backend(backend), interpret,
                         d_max, max_rounds)


def _apsp_fwd(w, backend, interpret, d_max, max_rounds):
    d = _apsp_forward(w, normalize_backend(backend), interpret,
                      d_max, max_rounds)
    return d, (w, d)


def _apsp_bwd(backend, interpret, d_max, max_rounds, res, g):
    w, d = res
    if resolve_backend(backend, w.shape[0]) == "ell-bf":
        return (_sp_dag_grad_ell(w, d, g, d_max),)
    return (_sp_dag_grad(w, d, g),)


apsp.defvjp(_apsp_fwd, _apsp_bwd)
