"""APSP backend registry (``ApspBackend``) and the shared SP-DAG
subgradient seam.

One public entry point, ``apsp(w, backend, interpret)``, closes an (N, N)
weight matrix over the tropical semiring.  The forward pass dispatches on
the backend registry:

* ``"squaring"``        — pure-jnp repeated (min,+) squaring (the legacy
  default path; ``O(N^3 log N)`` work, ``O(N^3)`` broadcast per step);
* ``"squaring-pallas"`` — repeated squaring on the Pallas tropical-matmul
  kernel (what ``use_pallas=True`` historically selected);
* ``"blocked-fw"``      — blocked Floyd-Warshall (``repro.kernels.fw``):
  one ``O(N^3)`` pass, ``O(N^2)`` live memory.  Compiled Pallas tiles on
  TPU (or with explicit ``interpret=True``); a ``lax.fori`` Floyd-Warshall
  on CPU where the interpreter would be the bottleneck;
* ``"auto"``            — ``"blocked-fw"`` for ``n >= AUTO_THRESHOLD``
  else ``"squaring"`` (a static shape decision, so it is jit-safe).

``normalize_backend`` maps the legacy ``use_pallas`` booleans threaded
through ``mcf``/``primal``/``engine`` onto registry names, so existing
call sites (``get_engine("dual-pallas")``, ``use_pallas=True``) keep
working unchanged.

**The subgradient seam.**  All backends share ONE ``jax.custom_vjp``
backward: a Bellman fixed-point adjoint that only needs the saved
``(w, D)`` pair.  At the fixed point ``D[s,t] = min_{k != t} D[s,k] +
w[k,t]`` (the diagonal is excluded so no cotangent leaks into the fixed
zero diagonal), so the backward peels one hop off the end of every
shortest path per sweep: the tie-split predecessor mask (relative
tolerance from PR 4) routes each pair's cotangent one edge back along
the SP-DAG, depositing the edge's share of ``dw`` as it goes, until the
mass drains onto the diagonal (path complete).  Consequences:

* subgradients are **identical across backends by construction** — the
  backward never sees which forward produced ``D``;
* per-pair gradient mass is a unit flow routed on shortest paths (what
  the Frank-Wolfe primal oracle requires);
* backward memory is ``O(N^2 * chunk)`` (t-chunked mask slabs) instead
  of the ``O(N^3)`` tie-mask of the per-matmul VJP, and backward work is
  ``O(diameter * N^3 / chunk-parallelism)`` — diameters of the graphs
  here are small.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import fw as kfw
from repro.kernels import ops as kops

__all__ = ["apsp", "normalize_backend", "resolve_backend", "BACKENDS",
           "AUTO_THRESHOLD", "_INF"]

_INF = 1.0e18   # non-edge sentinel: survives one add in float32 headroom

BACKENDS = ("squaring", "squaring-pallas", "blocked-fw", "auto")
AUTO_THRESHOLD = 512   # auto: blocked-fw at and above this padded size
_FW_TILE = 128         # Pallas tile for the blocked-fw flavor
_BWD_ELEMS = 1 << 25   # float budget for one (n, n, chunk) backward slab


def normalize_backend(backend: str | bool | None = None,
                      use_pallas: bool = False) -> str:
    """Map a backend spec (registry name, legacy ``use_pallas`` bool, or
    None) to a registry name.  ``None`` defers to ``use_pallas`` for
    compatibility: True -> "squaring-pallas", False -> "auto"."""
    if backend is None:
        return "squaring-pallas" if use_pallas else "auto"
    if isinstance(backend, bool):   # legacy positional use_pallas slot
        return "squaring-pallas" if backend else "squaring"
    if backend not in BACKENDS:
        raise ValueError(f"unknown APSP backend {backend!r}; "
                         f"known: {BACKENDS}")
    return backend


def resolve_backend(backend: str, n: int) -> str:
    """Resolve "auto" against a concrete (static) matrix size."""
    backend = normalize_backend(backend)
    if backend == "auto":
        return "blocked-fw" if n >= AUTO_THRESHOLD else "squaring"
    return backend


def _squaring_steps(n: int) -> int:
    return max(1, math.ceil(math.log2(max(n - 1, 2))))


def _apsp_forward(w: jax.Array, backend: str, interpret: bool | None):
    n = w.shape[0]
    kind = resolve_backend(backend, n)
    d = w.astype(jnp.float32)
    if kind == "blocked-fw":
        # the tiled Pallas kernel only pays off compiled (TPU); elsewhere
        # the lax.fori Floyd-Warshall is the fast flavor (the solvers
        # pre-resolve interpret=None to True on CPU, so an interpret bool
        # cannot distinguish "explicitly requested interpreter" here —
        # tests drive the 4-phase interpret path via kernels.fw directly)
        if jax.default_backend() != "tpu":
            return kfw.fw_apsp_jnp(d)
        pad = (-n) % _FW_TILE
        if pad:
            d = jnp.pad(d, ((0, pad), (0, pad)), constant_values=_INF)
        d = kfw.fw_apsp_pallas(d, t=_FW_TILE, interpret=interpret)
        return d[:n, :n] if pad else d
    for _ in range(_squaring_steps(n)):
        if kind == "squaring-pallas":
            d = jnp.minimum(d, kops.minplus_matmul(d, d, 128, interpret))
        else:
            d = jnp.minimum(d, jnp.min(d[:, :, None] + d[None, :, :],
                                       axis=1))
    return d


def _bwd_chunk(n: int) -> int:
    return max(1, min(n, _BWD_ELEMS // max(n * n, 1)))


def _sp_dag_grad(w: jax.Array, d: jax.Array, g: jax.Array) -> jax.Array:
    """Backward of the APSP closure: route the cotangent ``g`` on ``D``
    back along the shortest-path DAG of ``(w, D)``, one hop per sweep."""
    n = w.shape[0]
    eye = jnp.eye(n, dtype=bool)
    reach = d < _INF / 2
    # no gradient through the fixed zero diagonal or unreachable pairs
    # (D is locally constant at the sentinel there)
    u0 = jnp.where(reach & ~eye, g, 0.0).astype(jnp.float32)
    c = _bwd_chunk(n)
    pad = (-n) % c
    wf = w.astype(jnp.float32)
    df = d.astype(jnp.float32)
    if pad:
        wf = jnp.pad(wf, ((0, pad), (0, pad)), constant_values=_INF)
        df = jnp.pad(df, ((0, pad), (0, pad)), constant_values=_INF)
        u0 = jnp.pad(u0, ((0, pad), (0, pad)))
    m = n + pad
    eye_m = jnp.eye(m, dtype=bool)
    kidx = jnp.arange(m)

    def one_hop(u, dw):
        def chunk_body(j, acc):
            un, dwn = acc
            t0 = j * c
            wc = jax.lax.dynamic_slice_in_dim(wf, t0, c, axis=1)  # (m, c)
            dc = jax.lax.dynamic_slice_in_dim(df, t0, c, axis=1)
            uc = jax.lax.dynamic_slice_in_dim(u, t0, c, axis=1)
            s = df[:, :, None] + wc[None, :, :]                   # (m, m, c)
            # relative tie tolerance (PR 4): edge lengths span many
            # orders of magnitude under the dual's log-length ascent
            tol = 1e-6 * jnp.maximum(jnp.abs(dc), 1e-6)
            mask = s <= (dc + tol)[:, None, :]
            # k == t would tie via the zero diagonal every sweep and
            # stall the drain; the fixed point excludes it
            mask &= kidx[None, :, None] != (t0 + jnp.arange(c))[None, None, :]
            mf = mask.astype(jnp.float32)
            mf = mf / jnp.maximum(mf.sum(axis=1, keepdims=True), 1.0)
            mf = mf * uc[:, None, :]
            un = un + mf.sum(axis=2)                # cotangent, one hop back
            dep = jax.lax.dynamic_slice_in_dim(dwn, t0, c, axis=1)
            dwn = jax.lax.dynamic_update_slice_in_dim(
                dwn, dep + mf.sum(axis=0), t0, axis=1)
            return un, dwn

        return jax.lax.fori_loop(0, m // c, chunk_body,
                                 (jnp.zeros_like(u), dw))

    def cond(carry):
        u, _, it = carry
        return (it < m) & (jnp.max(jnp.abs(u)) > 0.0)

    def body(carry):
        u, dw, it = carry
        u2, dw2 = one_hop(u, dw)
        # mass arriving on the diagonal is a completed path
        return jnp.where(eye_m, 0.0, u2), dw2, it + 1

    _, dw, _ = jax.lax.while_loop(cond, body,
                                  (u0, jnp.zeros_like(wf), 0))
    if pad:
        dw = dw[:n, :n]
    return dw.astype(w.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def apsp(w: jax.Array, backend: str = "auto",
         interpret: bool | None = None) -> jax.Array:
    """All-pairs shortest path lengths of a dense weighted digraph.

    ``w``: (N, N) edge lengths, zero diagonal, ``_INF`` for non-edges
    (positive lengths; zero-length cycles make the subgradient tie-split
    ill-defined).  ``backend`` is an ``ApspBackend`` registry name (see
    module docstring); ``interpret`` is the Pallas escape hatch threaded
    to the kernels.  Differentiable on every backend via the shared
    fixed-point adjoint."""
    return _apsp_forward(w, normalize_backend(backend), interpret)


def _apsp_fwd(w, backend, interpret):
    d = _apsp_forward(w, normalize_backend(backend), interpret)
    return d, (w, d)


def _apsp_bwd(backend, interpret, res, g):
    w, d = res
    return (_sp_dag_grad(w, d, g),)


apsp.defvjp(_apsp_fwd, _apsp_bwd)
