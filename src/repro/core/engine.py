"""Unified throughput engines + the declarative sweep runner.

Every figure in the paper is the same experiment: build a topology, pick a
traffic matrix, measure max-concurrent-flow throughput, repeat over seeds.
This module gives that one API:

* ``ThroughputEngine`` — the protocol every solver backend implements:
  ``solve(topo, dem) -> ThroughputResult`` and a same-length
  ``solve_batch(topos, dems)``.
* ``ExactLPEngine`` — the HiGHS LP oracle (``repro.core.lp``); exact but
  sequential.
* ``DualEngine`` — the JAX dual solver (``repro.core.mcf``); a certified
  upper bound that converges to the optimum, and whose ``solve_batch``
  stacks all equal-size instances into ONE vmapped program (the paper's
  "20 runs per point" as a single device launch).  ``use_pallas=True``
  routes the (min,+) APSP inner loop through the Pallas TPU kernel.
* ``get_engine("exact" | "dual" | "dual-pallas" | "auto")`` — string
  registry; ``as_engine`` additionally passes engine instances through, so
  every driver accepts either.
* ``Sweep`` / ``run_sweep`` — a declarative (xs × runs) experiment: a build
  function, a named traffic pattern, and an engine.  All instances go
  through one ``solve_batch`` call, so batching engines see the whole
  sweep at once.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core import lp, mcf
from repro.core import traffic as traffic_mod
from repro.core.graphs import Topology, as_cap

__all__ = [
    "ThroughputResult",
    "ThroughputEngine",
    "ExactLPEngine",
    "DualEngine",
    "AutoEngine",
    "ENGINES",
    "get_engine",
    "as_engine",
    "SweepPoint",
    "Sweep",
    "run_sweep",
]


@dataclasses.dataclass(frozen=True)
class ThroughputResult:
    """Throughput of one (topology, demand) instance, engine-agnostic."""

    throughput: float        # θ: per-unit-demand max concurrent flow rate
    is_upper_bound: bool     # True: certified bound that converges to θ*
    engine: str              # registry name of the engine that produced it
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@runtime_checkable
class ThroughputEngine(Protocol):
    """Protocol for throughput solver backends."""

    name: str
    batches: bool   # True if solve_batch is cheaper than per-instance solves

    def solve(self, topo: Topology | np.ndarray,
              dem: np.ndarray) -> ThroughputResult: ...

    def solve_batch(self, topos: Sequence[Topology | np.ndarray],
                    dems: Sequence[np.ndarray]) -> list[ThroughputResult]: ...


def _check_batch_lengths(topos, dems) -> None:
    if len(topos) != len(dems):
        raise ValueError(f"topos ({len(topos)}) and dems ({len(dems)}) "
                         "must have equal length")


class ExactLPEngine:
    """Exact max-concurrent-flow via the HiGHS LP (``repro.core.lp``)."""

    name = "exact"
    batches = False

    def solve(self, topo, dem) -> ThroughputResult:
        res = lp.max_concurrent_flow(topo, dem, want_flows=False)
        return ThroughputResult(throughput=res.throughput,
                                is_upper_bound=False, engine=self.name,
                                meta={"status": res.status})

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        return [self.solve(t, d) for t, d in zip(topos, dems)]


class DualEngine:
    """Certified dual bound via JAX (``repro.core.mcf``), batchable.

    ``solve_batch`` groups instances by node count and runs each group as a
    single vmapped program; results come back in input order.
    """

    batches = True

    def __init__(self, use_pallas: bool = False, iters: int = 800,
                 lr: float = 0.08):
        self.use_pallas = use_pallas
        self.iters = iters
        self.lr = lr
        self.name = "dual-pallas" if use_pallas else "dual"

    def solve(self, topo, dem) -> ThroughputResult:
        res = mcf.solve_dual(topo, dem, iters=self.iters, lr=self.lr,
                             use_pallas=self.use_pallas)
        return ThroughputResult(
            throughput=res.throughput_ub, is_upper_bound=True,
            engine=self.name,
            meta={"iterations": res.iterations,
                  "final_ratio": res.final_ratio})

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        caps = [as_cap(t) for t in topos]
        dems = [np.asarray(d, np.float64) for d in dems]
        by_size: dict[int, list[int]] = {}
        for i, c in enumerate(caps):
            by_size.setdefault(c.shape[0], []).append(i)
        out: list[ThroughputResult | None] = [None] * len(caps)
        for n, idx in by_size.items():
            ubs = mcf.solve_dual_batch(
                np.stack([caps[i] for i in idx]),
                np.stack([dems[i] for i in idx]),
                iters=self.iters, lr=self.lr, use_pallas=self.use_pallas)
            for i, ub in zip(idx, ubs):
                out[i] = ThroughputResult(
                    throughput=float(ub), is_upper_bound=True,
                    engine=self.name,
                    meta={"iterations": self.iters,
                          "batch_size": len(idx), "nodes": n})
        return out


class AutoEngine:
    """Exact LP for small instances, dual bound beyond ``exact_max_nodes``."""

    name = "auto"
    batches = True

    def __init__(self, exact_max_nodes: int = 64):
        self.exact_max_nodes = exact_max_nodes
        self._exact = ExactLPEngine()
        self._dual = DualEngine()

    def _pick(self, topo) -> ThroughputEngine:
        n = as_cap(topo).shape[0]
        return self._exact if n <= self.exact_max_nodes else self._dual

    def solve(self, topo, dem) -> ThroughputResult:
        return self._pick(topo).solve(topo, dem)

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        exact_idx: list[int] = []
        dual_idx: list[int] = []
        for i, t in enumerate(topos):
            (exact_idx if self._pick(t) is self._exact
             else dual_idx).append(i)
        out: list[ThroughputResult | None] = [None] * len(topos)
        for eng, idx in ((self._exact, exact_idx), (self._dual, dual_idx)):
            if idx:
                sub = eng.solve_batch([topos[i] for i in idx],
                                      [dems[i] for i in idx])
                for i, r in zip(idx, sub):
                    out[i] = r
        return out


ENGINES: dict[str, Callable[[], ThroughputEngine]] = {
    "exact": ExactLPEngine,
    "dual": DualEngine,
    "dual-pallas": lambda **kw: DualEngine(use_pallas=True, **kw),
    "auto": AutoEngine,
}


def get_engine(name: str, **kw) -> ThroughputEngine:
    """Instantiate a registered engine by name (kwargs go to its ctor)."""
    try:
        factory = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; known: {sorted(ENGINES)}") from None
    return factory(**kw) if kw else factory()


def as_engine(engine: str | ThroughputEngine) -> ThroughputEngine:
    """Accept an engine instance or a registry name (deprecation shim for
    the old ``engine: str`` plumbing)."""
    if isinstance(engine, str):
        return get_engine(engine)
    return engine


# ---------------------------------------------------------------------------
# declarative sweeps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepPoint:
    x: float
    mean: float
    std: float
    values: tuple[float, ...]


@dataclasses.dataclass(frozen=True)
class Sweep:
    """One paper-style experiment: measure throughput at each ``x`` over
    ``runs`` seeded repetitions under a named traffic pattern."""

    xs: tuple[float, ...]
    runs: int = 3
    seed0: int = 0
    traffic: str = "permutation"
    traffic_kw: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def seeds(self) -> list[int]:
        return [self.seed0 + 1000 * rr for rr in range(self.runs)]


def run_sweep(sweep: Sweep,
              build_fn: Callable[[float, int], Topology],
              engine: str | ThroughputEngine = "exact") -> list[SweepPoint]:
    """Run a declarative sweep: build every (x, run) instance, solve them all
    in ONE ``solve_batch`` call (vmapped per instance size on batching
    engines), and aggregate per-x statistics.

    ``build_fn(x, seed) -> Topology``; the traffic pattern is drawn with seed
    ``seed + 1`` from ``sweep.traffic``.
    """
    eng = as_engine(engine)
    topos, dems = [], []
    for x in sweep.xs:
        for seed in sweep.seeds():
            topo = build_fn(x, seed)
            dem = traffic_mod.make(sweep.traffic, topo.servers, seed + 1,
                                   **sweep.traffic_kw)
            topos.append(topo)
            dems.append(dem)
    results = eng.solve_batch(topos, dems)
    points = []
    for pi, x in enumerate(sweep.xs):
        vals = [r.throughput
                for r in results[pi * sweep.runs:(pi + 1) * sweep.runs]]
        v = np.asarray(vals)
        points.append(SweepPoint(float(x), float(v.mean()), float(v.std()),
                                 tuple(vals)))
    return points
