"""Unified throughput engines + the declarative sweep runner.

Every figure in the paper is the same experiment: build a topology, pick a
traffic matrix, measure max-concurrent-flow throughput, repeat over seeds.
This module gives that one API:

* ``ThroughputEngine`` — the protocol every solver backend implements:
  ``solve(topo, dem) -> ThroughputResult`` and a same-length
  ``solve_batch(topos, dems)``.
* ``ExactLPEngine`` — the HiGHS LP oracle (``repro.core.lp``); exact but
  sequential.
* ``DualEngine`` — the JAX dual solver (``repro.core.mcf``); a certified
  upper bound that converges to the optimum, and whose ``solve_batch``
  pads instances up to size *buckets* (powers of two by default) and runs
  each bucket as ONE vmapped program — a whole mixed-size sweep compiles
  once per bucket instead of once per distinct topology size (the paper's
  "20 runs per point" as a single device launch).  ``use_pallas=True``
  routes the (min,+) APSP inner loop through the Pallas TPU kernel;
  ``interpret=None`` auto-detects compiled-vs-interpreter from the JAX
  backend.  ``tol > 0`` enables convergence-based early stopping.
* ``get_engine("exact" | "dual" | "dual-pallas" | "auto")`` — string
  registry; ``as_engine`` additionally passes engine instances through, so
  every driver accepts either.
* ``Sweep`` / ``run_sweep`` — a declarative (xs × runs) experiment: a build
  function, a named traffic pattern, and an engine.  All instances go
  through one ``solve_batch`` call, so batching engines see the whole
  sweep at once.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core import lp, mcf
from repro.core import traffic as traffic_mod
from repro.core.graphs import Topology, as_cap

__all__ = [
    "ThroughputResult",
    "ThroughputEngine",
    "ExactLPEngine",
    "DualEngine",
    "AutoEngine",
    "ENGINES",
    "get_engine",
    "as_engine",
    "bucket_size",
    "SweepPoint",
    "Sweep",
    "run_sweep",
]


def bucket_size(n: int, mode: str | int | None) -> int:
    """Padded size for an ``n``-node instance under a bucketing ``mode``:
    ``"pow2"`` (next power of two, floor 8), ``"mult128"`` (next multiple
    of 128 — TPU tile-aligned), an ``int`` m (next multiple of m), or
    ``None``/``"none"``/``"exact"`` (no padding: group by exact size)."""
    if mode in (None, "none", "exact"):
        return n
    if mode == "pow2":
        return max(8, 1 << (n - 1).bit_length())
    if mode == "mult128":
        mode = 128
    if isinstance(mode, int) and mode > 0:
        return -(-n // mode) * mode
    raise ValueError(f"unknown bucket mode {mode!r}; expected 'pow2', "
                     "'mult128', a positive int, or None")


@dataclasses.dataclass(frozen=True)
class ThroughputResult:
    """Throughput of one (topology, demand) instance, engine-agnostic."""

    throughput: float        # θ: per-unit-demand max concurrent flow rate
    is_upper_bound: bool     # True: certified bound that converges to θ*
    engine: str              # registry name of the engine that produced it
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@runtime_checkable
class ThroughputEngine(Protocol):
    """Protocol for throughput solver backends."""

    name: str
    batches: bool   # True if solve_batch is cheaper than per-instance solves

    def solve(self, topo: Topology | np.ndarray,
              dem: np.ndarray) -> ThroughputResult: ...

    def solve_batch(self, topos: Sequence[Topology | np.ndarray],
                    dems: Sequence[np.ndarray]) -> list[ThroughputResult]: ...


def _check_batch_lengths(topos, dems) -> None:
    if len(topos) != len(dems):
        raise ValueError(f"topos ({len(topos)}) and dems ({len(dems)}) "
                         "must have equal length")


class ExactLPEngine:
    """Exact max-concurrent-flow via the HiGHS LP (``repro.core.lp``)."""

    name = "exact"
    batches = False

    def solve(self, topo, dem) -> ThroughputResult:
        res = lp.max_concurrent_flow(topo, dem, want_flows=False)
        return ThroughputResult(throughput=res.throughput,
                                is_upper_bound=False, engine=self.name,
                                meta={"status": res.status})

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        return [self.solve(t, d) for t, d in zip(topos, dems)]


class DualEngine:
    """Certified dual bound via JAX (``repro.core.mcf``), batchable.

    ``solve_batch`` groups instances into size buckets (``bucket``:
    ``"pow2"`` by default — see ``bucket_size``), pads each group to its
    largest member (an equal-size group therefore pads nothing), and runs
    each bucket as a single vmapped program, so a mixed-size sweep triggers
    one XLA compile per bucket rather than one per distinct node count.
    Results come back in
    input order, each carrying the instance's actual ``iterations`` and
    ``final_ratio`` in ``meta``.  ``tol > 0`` enables per-instance
    convergence-based early stopping (checked every ``check_every`` steps);
    ``interpret=None`` auto-detects the Pallas execution mode from the JAX
    backend.
    """

    batches = True

    def __init__(self, use_pallas: bool = False, iters: int = 800,
                 lr: float = 0.08, tol: float = 0.0, check_every: int = 25,
                 bucket: str | int | None = "pow2",
                 interpret: bool | None = None):
        self.use_pallas = use_pallas
        self.iters = iters
        self.lr = lr
        self.tol = tol
        self.check_every = check_every
        bucket_size(1, bucket)   # fail fast on an unknown bucket mode
        self.bucket = bucket
        self.interpret = interpret
        self.name = "dual-pallas" if use_pallas else "dual"

    def _solver_kw(self) -> dict:
        return dict(iters=self.iters, lr=self.lr, tol=self.tol,
                    check_every=self.check_every,
                    use_pallas=self.use_pallas, interpret=self.interpret)

    def solve(self, topo, dem) -> ThroughputResult:
        res = mcf.solve_dual(topo, dem, **self._solver_kw())
        return ThroughputResult(
            throughput=res.throughput_ub, is_upper_bound=True,
            engine=self.name,
            meta={"iterations": res.iterations,
                  "final_ratio": res.final_ratio})

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        caps = [as_cap(t) for t in topos]
        dems = [np.asarray(d) for d in dems]
        by_bucket: dict[int, list[int]] = {}
        for i, c in enumerate(caps):
            by_bucket.setdefault(bucket_size(c.shape[0], self.bucket),
                                 []).append(i)
        out: list[ThroughputResult | None] = [None] * len(caps)
        for bucket, idx in sorted(by_bucket.items()):
            # pad to the largest member, not the bucket ceiling: same one
            # compile per bucket within this call, but an equal-size group
            # (the per-figure common case) pads nothing at all
            size = max(caps[i].shape[0] for i in idx)
            capp = np.zeros((len(idx), size, size), np.float32)
            demp = np.zeros((len(idx), size, size), np.float32)
            n_valid = np.empty(len(idx), np.int32)
            for b, i in enumerate(idx):
                n = caps[i].shape[0]
                capp[b, :n, :n] = caps[i]
                demp[b, :n, :n] = dems[i]
                n_valid[b] = n
            res = mcf.solve_dual_batch(capp, demp, n_valid=n_valid,
                                       **self._solver_kw())
            for b, i in enumerate(idx):
                out[i] = ThroughputResult(
                    throughput=float(res.throughput_ub[b]),
                    is_upper_bound=True, engine=self.name,
                    meta={"iterations": int(res.iterations[b]),
                          "final_ratio": float(res.final_ratio[b]),
                          "batch_size": len(idx), "bucket": bucket,
                          "padded_n": size, "nodes": int(n_valid[b])})
        return out


class AutoEngine:
    """Exact LP for small instances, dual bound beyond ``exact_max_nodes``."""

    name = "auto"
    batches = True

    def __init__(self, exact_max_nodes: int = 64, **dual_kw):
        self.exact_max_nodes = exact_max_nodes
        self._exact = ExactLPEngine()
        self._dual = DualEngine(**dual_kw)

    def _pick(self, topo) -> ThroughputEngine:
        n = as_cap(topo).shape[0]
        return self._exact if n <= self.exact_max_nodes else self._dual

    def solve(self, topo, dem) -> ThroughputResult:
        return self._pick(topo).solve(topo, dem)

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        exact_idx: list[int] = []
        dual_idx: list[int] = []
        for i, t in enumerate(topos):
            (exact_idx if self._pick(t) is self._exact
             else dual_idx).append(i)
        out: list[ThroughputResult | None] = [None] * len(topos)
        for eng, idx in ((self._exact, exact_idx), (self._dual, dual_idx)):
            if idx:
                sub = eng.solve_batch([topos[i] for i in idx],
                                      [dems[i] for i in idx])
                for i, r in zip(idx, sub):
                    out[i] = r
        return out


ENGINES: dict[str, Callable[[], ThroughputEngine]] = {
    "exact": ExactLPEngine,
    "dual": DualEngine,
    "dual-pallas": lambda **kw: DualEngine(use_pallas=True, **kw),
    "auto": AutoEngine,
}


def get_engine(name: str, **kw) -> ThroughputEngine:
    """Instantiate a registered engine by name (kwargs go to its ctor)."""
    try:
        factory = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; known: {sorted(ENGINES)}") from None
    return factory(**kw) if kw else factory()


def as_engine(engine: str | ThroughputEngine) -> ThroughputEngine:
    """Accept an engine instance or a registry name (deprecation shim for
    the old ``engine: str`` plumbing)."""
    if isinstance(engine, str):
        return get_engine(engine)
    return engine


# ---------------------------------------------------------------------------
# declarative sweeps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepPoint:
    x: float
    mean: float
    std: float
    values: tuple[float, ...]


@dataclasses.dataclass(frozen=True)
class Sweep:
    """One paper-style experiment: measure throughput at each ``x`` over
    ``runs`` seeded repetitions under a named traffic pattern."""

    xs: tuple[float, ...]
    runs: int = 3
    seed0: int = 0
    traffic: str = "permutation"
    traffic_kw: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def seeds(self) -> list[int]:
        return [self.seed0 + 1000 * rr for rr in range(self.runs)]


def run_sweep(sweep: Sweep,
              build_fn: Callable[[float, int], Topology],
              engine: str | ThroughputEngine = "exact") -> list[SweepPoint]:
    """Run a declarative sweep: build every (x, run) instance, solve them all
    in ONE ``solve_batch`` call (vmapped per instance size on batching
    engines), and aggregate per-x statistics.

    ``build_fn(x, seed) -> Topology``; the traffic pattern is drawn with seed
    ``seed + 1`` from ``sweep.traffic``.
    """
    eng = as_engine(engine)
    topos, dems = [], []
    for x in sweep.xs:
        for seed in sweep.seeds():
            topo = build_fn(x, seed)
            dem = traffic_mod.make(sweep.traffic, topo.servers, seed + 1,
                                   **sweep.traffic_kw)
            topos.append(topo)
            dems.append(dem)
    results = eng.solve_batch(topos, dems)
    points = []
    for pi, x in enumerate(sweep.xs):
        vals = [r.throughput
                for r in results[pi * sweep.runs:(pi + 1) * sweep.runs]]
        v = np.asarray(vals)
        points.append(SweepPoint(float(x), float(v.mean()), float(v.std()),
                                 tuple(vals)))
    return points
