"""Unified throughput engines + the declarative sweep runner.

Every figure in the paper is the same experiment: build a topology, pick a
traffic matrix, measure max-concurrent-flow throughput, repeat over seeds.
This module gives that one API:

* ``ThroughputEngine`` — the protocol every solver backend implements:
  ``solve(topo, dem) -> ThroughputResult`` and a same-length
  ``solve_batch(topos, dems)``.
* ``ExactLPEngine`` — the HiGHS LP oracle (``repro.core.lp``); exact but
  sequential.
* ``DualEngine`` — the JAX dual solver (``repro.core.mcf``); a certified
  upper bound that converges to the optimum.  Its ``solve_batch`` delegates
  to the ``repro.core.plan.BatchPlan`` execution core: instances are
  grouped into size *buckets* (powers of two by default), each bucket is
  split into chunks under a ``max_lanes`` budget, every chunk's batch axis
  is sharded across ``devices`` local devices, and all chunks dispatch
  asynchronously with ONE host sync at the end — a whole mixed-size sweep
  compiles once per (bucket, chunk-shape) and keeps every device busy.
  ``use_pallas=True`` routes the (min,+) APSP inner loop through the
  Pallas TPU kernel; ``interpret=None`` auto-detects
  compiled-vs-interpreter from the JAX backend.  ``tol > 0`` enables
  convergence-based early stopping.
* ``PrimalEngine`` — the Frank–Wolfe primal solver (``repro.core.primal``);
  a certified LOWER bound from an explicit feasible flow.  Same planner,
  same knobs: primal lanes ride the same buckets/chunks/sharding.
* ``CertifiedEngine`` — the fused bracket engine: one primal program per
  lane computes both the FW lower bound and the dual descent's upper bound
  through one ``BatchPlan``, and every result carries ``meta["lb"]`` /
  ``meta["ub"]`` / ``meta["gap"]``.
* ``EcmpEngine`` / ``KspEngine`` — routing-restricted lower bounds
  (``repro.core.routing``): deployable throughput under ECMP and
  k-shortest-path multipath routing, each carrying the ideal bracket's
  upper bound and ``meta["ideal_gap_pct"]`` (the certified price of the
  routing restriction).
* ``get_engine("exact" | "dual" | "dual-pallas" | "primal" | "certified" |
  "ecmp" | "ksp" | "auto")`` — string registry; ``as_engine``
  additionally passes engine instances through, so every driver accepts
  either.
* ``Sweep`` / ``run_sweep`` / ``run_sweeps`` — declarative (xs × runs)
  experiments: a build function, a named traffic pattern, and an engine.
  ``run_sweeps`` routes EVERY instance of a whole figure family (many
  sweeps) through one ``solve_batch`` call — i.e. one ``BatchPlan`` on
  batching engines — and aggregates brackets (``lb_mean``/``gap_max``)
  into each ``SweepPoint`` when the engine provides them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core import adversarial as adversarial_mod
from repro.core import aotcache, lp, mcf, primal, routing
from repro.core import apsp as apsp_mod
from repro.core import traffic as traffic_mod
from repro.core.graphs import Topology, as_cap
from repro.core.plan import (  # noqa: F401  (bucket_size re-exported)
    BatchPlan, InstanceSolve, bucket_size,
)

__all__ = [
    "ThroughputResult",
    "ThroughputEngine",
    "ExactLPEngine",
    "DualEngine",
    "PrimalEngine",
    "CertifiedEngine",
    "EcmpEngine",
    "KspEngine",
    "AutoEngine",
    "AdversarialEngine",
    "ENGINES",
    "get_engine",
    "as_engine",
    "bucket_size",
    "SweepPoint",
    "Sweep",
    "run_sweep",
    "run_sweeps",
]


@dataclasses.dataclass(frozen=True)
class ThroughputResult:
    """Throughput of one (topology, demand) instance, engine-agnostic.

    ``throughput`` is θ, the max concurrent flow rate per unit of demand:
    every entry of ``dem[N, N]`` can be routed simultaneously at rate
    θ·dem[s, t] within the capacities ``cap[N, N]`` (both in units of the
    base line-speed — 1 = one 1GbE link's worth).  θ ≥ 1 means "full
    throughput" in the paper's sense.

    ``bound`` says what kind of claim ``throughput`` is: ``"exact"`` (the
    LP optimum), ``"upper"`` / ``"lower"`` (a certified one-sided bound
    that converges to θ*), or ``"bracket"`` (an upper bound whose ``meta``
    carries the full ``lb``/``ub``/``gap`` bracket).  It defaults from
    ``is_upper_bound`` for backwards compatibility.
    """

    throughput: float        # θ: per-unit-demand max concurrent flow rate
    is_upper_bound: bool     # True: certified bound that converges to θ*
    engine: str              # registry name of the engine that produced it
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    bound: str = ""          # "exact" | "upper" | "lower" | "bracket"

    def __post_init__(self):
        if not self.bound:
            object.__setattr__(self, "bound",
                               "upper" if self.is_upper_bound else "exact")


@runtime_checkable
class ThroughputEngine(Protocol):
    """Protocol for throughput solver backends.

    ``solve`` takes one ``Topology`` (or a bare symmetric ``cap[N, N]``
    capacity matrix, units of the base line-speed) and a ``dem[N, N]``
    demand matrix (unit-demand flows per switch pair) and returns a
    ``ThroughputResult`` whose ``bound`` field names the certification
    (exact / upper / lower / bracket).  ``solve_batch`` is positional and
    same-length: result ``i`` answers instance ``i``.  ``batches`` is
    True when ``solve_batch`` is cheaper than per-instance ``solve``
    calls (drivers use it to keep early-exit loops on sequential
    engines)."""

    name: str
    batches: bool   # True if solve_batch is cheaper than per-instance solves

    def solve(self, topo: Topology | np.ndarray,
              dem: np.ndarray) -> ThroughputResult: ...

    def solve_batch(self, topos: Sequence[Topology | np.ndarray],
                    dems: Sequence[np.ndarray]) -> list[ThroughputResult]: ...


def _check_batch_lengths(topos, dems) -> None:
    if len(topos) != len(dems):
        raise ValueError(f"topos ({len(topos)}) and dems ({len(dems)}) "
                         "must have equal length")


class ExactLPEngine:
    """Exact max-concurrent-flow via the HiGHS LP (``repro.core.lp``):
    ``bound="exact"`` — the returned θ IS the optimum, no certification
    gap.  Sequential (one LP per instance) and only tractable at small N
    (minutes beyond ~100 nodes); the JAX engines take over from there."""

    name = "exact"
    batches = False

    def solve(self, topo, dem) -> ThroughputResult:
        res = lp.max_concurrent_flow(topo, dem, want_flows=False)
        return ThroughputResult(throughput=res.throughput,
                                is_upper_bound=False, engine=self.name,
                                meta={"status": res.status})

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        return [self.solve(t, d) for t, d in zip(topos, dems)]


class _PlannedEngine:
    """Shared planner plumbing of every JAX solver engine.

    ``solve_batch`` delegates to ``repro.core.plan.BatchPlan``: instances
    are grouped into size buckets (``bucket``: ``"pow2"`` by default — see
    ``bucket_size``), each padded to its largest member (an equal-size
    group therefore pads nothing); each bucket is split into chunks of at
    most ``max_lanes`` batch rows (``None`` = the whole bucket in one
    launch; a budget below the device count is raised to one lane per
    device — every launch spans all ``devices``, so that is the floor on
    rows per launch); each chunk's batch axis is sharded over ``devices``
    local devices (``None`` = all of them) and all chunks dispatch
    asynchronously, so a mixed-size sweep triggers one XLA compile per
    (bucket, chunk-shape) and one host sync total.  Results come back in
    input order, each carrying the solver's per-instance outputs plus its
    plan placement (``bucket``/``chunk``/``devices``/``plan`` stats) in
    ``meta``; ``last_plan`` keeps the most recent ``PlanStats``.  ``tol >
    0`` enables per-instance convergence-based early stopping (checked
    every ``check_every`` steps); ``interpret=None`` auto-detects the
    Pallas execution mode from the JAX backend.

    ``on_disconnected`` pins what happens when a demanded (s, t) pair has
    no path (failure scenarios produce these routinely): ``None`` (default)
    solves as-is — the dual ratio legitimately drives the bound toward the
    true θ* = 0 — ``"raise"`` rejects the instance before solving, and
    ``"drop"`` zeroes the unroutable demand, solves the routable remainder
    and reports the zeroed share in ``meta["dropped_demand_fraction"]``
    (0.0 when nothing was dropped).  An instance whose demand is entirely
    unroutable is never dispatched to a solver under ``"drop"``: it
    reports throughput 0 (lb = ub = 0 on bracket engines) with
    ``meta["disconnected"] = True``.

    Subclasses set ``solver`` (the ``plan.SOLVERS`` key) and implement
    ``solve`` plus ``_result`` (how one ``InstanceSolve`` becomes a
    ``ThroughputResult``).
    """

    batches = True
    solver: str = "dual"

    def __init__(self, use_pallas: bool = False, iters: int = 800,
                 lr: float = 0.08, tol: float = 0.0, check_every: int = 25,
                 bucket: str | int | None = "pow2",
                 interpret: bool | None = None,
                 devices: int | None = None,
                 max_lanes: int | None = None,
                 on_disconnected: str | None = None,
                 backend: str | None = None,
                 coarsen: bool = True,
                 aot_cache: bool | str | None = None,
                 d_max: int | None = None,
                 max_rounds: int | None = None):
        self.use_pallas = use_pallas
        self.iters = iters
        self.lr = lr
        self.tol = tol
        self.check_every = check_every
        bucket_size(1, bucket)   # fail fast on an unknown bucket mode
        self.bucket = bucket
        self.interpret = interpret
        self.devices = devices
        self.max_lanes = max_lanes
        if on_disconnected not in (None, "raise", "drop"):
            raise ValueError("on_disconnected must be None, 'raise' or "
                             f"'drop', got {on_disconnected!r}")
        self.on_disconnected = on_disconnected
        # backend: ApspBackend registry name; None defers to the legacy
        # use_pallas flag (True -> "squaring-pallas", False -> "auto")
        self.backend = apsp_mod.normalize_backend(backend, use_pallas)
        # coarsen: contract server leaf nodes (Topology.server_nodes) onto
        # their switches before planning, so plan lanes carry switch-only
        # graphs with lifted demand (exact; see Topology.coarsen)
        self.coarsen = coarsen
        # aot_cache: persistent ahead-of-time compile cache.  None defers
        # to $REPRO_AOT_CACHE; True uses the default cache dir; a string
        # is the cache dir itself.  Off by default.
        self._aot = aotcache.resolve(aot_cache)
        # d_max / max_rounds: ell-bf statics (table width / relaxation-round
        # cap).  None lets BatchPlan.execute compute per-chunk density hints
        # from the unpadded members (see plan._density_hints).
        self.d_max = d_max
        self.max_rounds = max_rounds
        self.last_plan = None    # PlanStats of the most recent solve_batch

    def _solver_kw(self) -> dict:
        kw = dict(iters=self.iters, lr=self.lr, tol=self.tol,
                  check_every=self.check_every, backend=self.backend,
                  interpret=self.interpret, aot=self._aot)
        # only pin the ell-bf statics when set, so the planner's per-chunk
        # density hints stay in charge otherwise
        if self.d_max is not None:
            kw["d_max"] = self.d_max
        if self.max_rounds is not None:
            kw["max_rounds"] = self.max_rounds
        return kw

    def _coarsen_instances(self, topos, dems):
        """Contract server-expanded topologies (``server_nodes`` marked)
        onto switch-only graphs with lifted demand.  Instances without
        server nodes pass through untouched."""
        if not self.coarsen:
            return list(topos), list(dems)
        out_t, out_d = [], []
        for t, d in zip(topos, dems):
            if isinstance(t, Topology) and t.server_nodes is not None:
                t, d = t.coarsen(d)
            out_t.append(t)
            out_d.append(d)
        return out_t, out_d

    def plan(self, topos, dems) -> BatchPlan:
        """The ``BatchPlan`` this engine would execute for these instances
        (exposed for introspection and tests)."""
        _check_batch_lengths(topos, dems)
        topos, dems = self._coarsen_instances(topos, dems)
        return BatchPlan.build(topos, dems, bucket=self.bucket,
                               max_lanes=self.max_lanes,
                               devices=self.devices)

    def _apply_disconnection_policy(self, topos, dems):
        """Apply ``on_disconnected`` to one pile: returns (dems, dropped)
        where ``dropped[i]`` is the zeroed demand share (None on the
        pass-through policy).  ``dropped[i] == 1.0`` marks an instance
        that must not reach a solver (no routable demand at all)."""
        if self.on_disconnected is None:
            return list(dems), [None] * len(dems)
        kept, dropped = [], []
        for i, (t, d) in enumerate(zip(topos, dems)):
            d2, frac = mcf.drop_disconnected(as_cap(t), d)
            if frac > 0 and self.on_disconnected == "raise":
                raise ValueError(
                    f"instance {i}: {100 * frac:.1f}% of the demand is "
                    "between disconnected switches; use "
                    "on_disconnected='drop' to solve the routable share")
            kept.append(d2)
            dropped.append(frac)
        return kept, dropped

    def _disconnected_result(self) -> ThroughputResult:
        """The fully-unroutable instance: θ* = 0 by definition, certified
        on both sides without running a solver."""
        s = InstanceSolve(value=0.0, iterations=0,
                          meta={"ub": 0.0, "final_ratio": 0.0,
                                "final_util": 0.0, "disconnected": True})
        return self._result(s)

    @staticmethod
    def _with_dropped(r: ThroughputResult,
                      frac: float | None) -> ThroughputResult:
        if frac is None:
            return r
        return dataclasses.replace(
            r, meta={**r.meta, "dropped_demand_fraction": frac})

    def _solve_preprocessed(self, topo, dem):
        """One-instance coarsen + ``on_disconnected`` preamble for
        ``solve``: (topo, kept_dem, dropped_fraction,
        short_circuit_result_or_None)."""
        (topo,), (dem,) = self._coarsen_instances([topo], [dem])
        dems, dropped = self._apply_disconnection_policy([topo], [dem])
        frac = dropped[0]
        if frac is not None and frac >= 1.0:
            return topo, dems[0], frac, self._with_dropped(
                self._disconnected_result(), frac)
        return topo, dems[0], frac, None

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        topos, dems = self._coarsen_instances(topos, dems)
        dems, dropped = self._apply_disconnection_policy(topos, dems)
        live = [i for i, f in enumerate(dropped) if f is None or f < 1.0]
        plan = self.plan([topos[i] for i in live], [dems[i] for i in live])
        self.last_plan = plan.stats
        solved = plan.execute(solver=self.solver, **self._solver_kw())
        out: list[ThroughputResult] = [self._disconnected_result()
                                       for _ in topos]
        for i, s in zip(live, solved):
            out[i] = self._result(s)
        return [self._with_dropped(r, f) for r, f in zip(out, dropped)]


class DualEngine(_PlannedEngine):
    """Certified dual UPPER bound via JAX (``repro.core.mcf``):
    ``bound="upper"`` — θ* ≤ ``throughput`` at every iterate, converging
    to θ* as the descent proceeds.  Batchable through the ``BatchPlan``
    execution core (see ``_PlannedEngine``); ``meta`` carries
    ``iterations`` and ``final_ratio`` (the last iterate's bound — its
    distance from ``throughput`` is a convergence probe)."""

    solver = "dual"

    def __init__(self, use_pallas: bool = False, **kw):
        super().__init__(use_pallas=use_pallas, **kw)
        self.name = ("dual-pallas" if self.backend == "squaring-pallas"
                     else "dual")

    def solve(self, topo, dem) -> ThroughputResult:
        topo, dem, frac, short = self._solve_preprocessed(topo, dem)
        if short is not None:
            return short
        res = mcf.solve_dual(topo, dem, **self._solver_kw())
        return self._with_dropped(ThroughputResult(
            throughput=res.throughput_ub, is_upper_bound=True,
            engine=self.name,
            meta={"iterations": res.iterations,
                  "final_ratio": res.final_ratio}), frac)

    def _result(self, s) -> ThroughputResult:
        return ThroughputResult(throughput=s.value, is_upper_bound=True,
                                engine=self.name, meta=s.meta)


class PrimalEngine(_PlannedEngine):
    """Certified primal LOWER bound via Frank–Wolfe shortest-path routing
    (``repro.core.primal``): ``bound="lower"`` — an explicit feasible
    flow routes every demand at rate ``throughput``, so θ* ≥
    ``throughput`` is a constructive proof.  The driving dual descent's
    free upper bound rides along in ``meta["ub"]``.  Same planner, same
    knobs as ``DualEngine`` — primal lanes reuse the same
    buckets/chunks/device sharding."""

    name = "primal"
    solver = "primal"

    def solve(self, topo, dem) -> ThroughputResult:
        topo, dem, frac, short = self._solve_preprocessed(topo, dem)
        if short is not None:
            return short
        res = primal.solve_primal(topo, dem, **self._solver_kw())
        return self._with_dropped(ThroughputResult(
            throughput=res.throughput_lb, is_upper_bound=False,
            engine=self.name, bound="lower",
            meta={"iterations": res.iterations,
                  "final_util": res.final_util,
                  "ub": res.throughput_ub}), frac)

    def _result(self, s) -> ThroughputResult:
        return ThroughputResult(throughput=s.value, is_upper_bound=False,
                                engine=self.name, bound="lower", meta=s.meta)


def _bracket(lb: float, ub: float, meta: Mapping[str, Any],
             engine: str) -> ThroughputResult:
    gap = (ub - lb) / max(ub, 1e-30)
    meta = {k: v for k, v in meta.items() if k != "ub"}
    return ThroughputResult(
        throughput=ub, is_upper_bound=True, engine=engine, bound="bracket",
        meta={"lb": lb, "ub": ub, "gap": gap, **meta})


class CertifiedEngine(PrimalEngine):
    """Certified (lb, ub, gap) brackets from ONE fused program per lane:
    ``bound="bracket"`` — lb ≤ θ* ≤ ub is provable, with ``gap`` =
    (ub−lb)/ub the relative width.  The Frank–Wolfe primal average
    (lower bound) and the dual descent it rides on (upper bound) share
    each iteration's APSP forward+backward, so dual+primal run through
    one ``BatchPlan`` at roughly the cost of either alone.
    ``throughput`` is the upper bound (it converges to θ*);
    ``meta["lb"]``/``meta["ub"]``/``meta["gap"]`` carry the bracket —
    pass/fail criteria should judge ``meta["lb"]`` (what
    ``vl2.supports_full_throughput`` does)."""

    name = "certified"

    def solve(self, topo, dem) -> ThroughputResult:
        topo, dem, frac, short = self._solve_preprocessed(topo, dem)
        if short is not None:
            return short
        res = primal.solve_primal(topo, dem, **self._solver_kw())
        return self._with_dropped(
            _bracket(res.throughput_lb, res.throughput_ub,
                     {"iterations": res.iterations,
                      "final_util": res.final_util}, self.name), frac)

    def _result(self, s) -> ThroughputResult:
        return _bracket(s.value, s.meta["ub"], s.meta, self.name)


def _ideal_gap_pct(lb: float, ub: float) -> float:
    """Certified price of a routing restriction, in percent of the ideal
    upper bound (0.0 on degenerate ub <= 0 instances)."""
    return 100.0 * (ub - lb) / ub if ub > 0 else 0.0


class EcmpEngine(_PlannedEngine):
    """Routing-restricted LOWER bound under ECMP (``repro.core.routing``):
    ``bound="lower"`` — an explicit equal-cost equal-split routing
    carries every demand at rate ``throughput``, so the deployable
    throughput under the routing operators actually run is >=
    ``throughput``.  The fused ideal dual descent's upper bound rides
    along in ``meta["ub"]`` and ``meta["ideal_gap_pct"]`` reports the
    certified price of the restriction (the Jellyfish gap).  Same
    planner, same knobs as ``DualEngine`` plus ``hops`` (fixed-point
    propagation depth; default N always covers the diameter)."""

    name = "ecmp"
    solver = "ecmp"
    _single = staticmethod(routing.solve_ecmp)

    def __init__(self, hops: int | None = None, **kw):
        super().__init__(**kw)
        self.hops = hops

    def _solver_kw(self) -> dict:
        kw = super()._solver_kw()
        if self.hops is not None:
            kw["hops"] = self.hops
        return kw

    def solve(self, topo, dem) -> ThroughputResult:
        topo, dem, frac, short = self._solve_preprocessed(topo, dem)
        if short is not None:
            return short
        res = self._single(topo, dem, **self._solver_kw())
        s = InstanceSolve(value=res.throughput_lb, iterations=res.iterations,
                          meta={"iterations": res.iterations,
                                "final_util": res.final_util,
                                "ub": res.throughput_ub})
        return self._with_dropped(self._result(s), frac)

    def _result(self, s) -> ThroughputResult:
        meta = {**s.meta,
                "ideal_gap_pct": _ideal_gap_pct(s.value, s.meta["ub"])}
        return ThroughputResult(throughput=s.value, is_upper_bound=False,
                                engine=self.name, bound="lower", meta=meta)


class KspEngine(EcmpEngine):
    """Routing-restricted LOWER bound under k-shortest-path multipath
    routing (``repro.core.routing``): multiplicative weights over each
    pair's ``k`` shortest simple paths, floored by the ECMP baseline it
    deviates from — so ``ecmp <= ksp(k) <= exact`` holds mechanically
    (see the routing module docstring).  Knobs: ``k`` (paths per pair,
    default 8) and ``max_hops`` (per-path hop budget; default
    min(N-1, 12), resolved from the padded width so refill rounds share
    compile keys); ``meta`` matches ``EcmpEngine``'s."""

    name = "ksp"
    solver = "ksp"
    _single = staticmethod(routing.solve_ksp)

    def __init__(self, k: int = routing.DEFAULT_K,
                 max_hops: int | None = None, **kw):
        super().__init__(**kw)
        self.k = k
        self.max_hops = max_hops

    def _solver_kw(self) -> dict:
        kw = super()._solver_kw()
        kw["k"] = self.k
        if self.max_hops is not None:
            kw["max_hops"] = self.max_hops
        return kw


class AutoEngine:
    """Exact LP for small instances, dual bound beyond ``exact_max_nodes``
    — so a mixed batch returns ``bound="exact"`` results for small
    instances and ``bound="upper"`` beyond the threshold (check
    per-result ``bound``, not the engine name).

    ``dual_kw`` (including the planner knobs ``devices``/``max_lanes``/
    ``bucket``) forwards to the inner ``DualEngine``; the dual share of a
    batch goes through one ``BatchPlan`` (``last_plan`` proxies its stats).
    """

    name = "auto"
    batches = True

    def __init__(self, exact_max_nodes: int = 64, **dual_kw):
        self.exact_max_nodes = exact_max_nodes
        self._exact = ExactLPEngine()
        self._dual = DualEngine(**dual_kw)

    @property
    def devices(self) -> int | None:
        return self._dual.devices

    @property
    def max_lanes(self) -> int | None:
        return self._dual.max_lanes

    @property
    def last_plan(self):
        return self._dual.last_plan

    def _pick(self, topo) -> ThroughputEngine:
        n = as_cap(topo).shape[0]
        return self._exact if n <= self.exact_max_nodes else self._dual

    def solve(self, topo, dem) -> ThroughputResult:
        return self._pick(topo).solve(topo, dem)

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        exact_idx: list[int] = []
        dual_idx: list[int] = []
        for i, t in enumerate(topos):
            (exact_idx if self._pick(t) is self._exact
             else dual_idx).append(i)
        out: list[ThroughputResult | None] = [None] * len(topos)
        for eng, idx in ((self._exact, exact_idx), (self._dual, dual_idx)):
            if idx:
                sub = eng.solve_batch([topos[i] for i in idx],
                                      [dems[i] for i in idx])
                for i, r in zip(idx, sub):
                    out[i] = r
        return out


class AdversarialEngine:
    """Worst-case-traffic evaluation: ``solve(topo, dem)`` IGNORES the
    usual "score this demand" contract and instead searches the hose
    polytope for the demand that minimises the topology's throughput
    (``repro.core.adversarial.find_worst_tm``), using ``dem`` (when
    given) as the fixed uniform baseline in lane 0 of every search
    round.  ``bound="bracket"``: ``throughput`` is the certified dual
    upper bound of the WORST TM found, ``meta`` carries the full
    certificate — ``lb``/``ub``/``gap`` for that TM, the TM itself
    (``meta["tm"]``), the baseline's bracket, and
    ``meta["uniform_gap_pct"]`` (how much certified headroom the
    adversary destroyed relative to the baseline).

    Ctor kwargs forward to ``find_worst_tm`` (``rounds``,
    ``candidates``, ``lr_tm``, the inner dual-solver knobs, planner
    knobs).  ``batches=False``: each topology runs its own multi-round
    search — batching happens INSIDE a search (one ``BatchPlan.execute``
    over the candidate fleet per round), not across topologies."""

    name = "adversarial"
    batches = False

    def __init__(self, **search_kw):
        self.search_kw = search_kw

    def solve(self, topo, dem=None, *, seed: int = 0) -> ThroughputResult:
        res = adversarial_mod.find_worst_tm(
            topo, seed=seed, baseline=dem, **self.search_kw)
        return _bracket(res.lb, res.ub,
                        {"tm": res.tm,
                         "uniform_gap_pct": res.uniform_gap_pct,
                         "baseline_lb": res.baseline_lb,
                         "baseline_ub": res.baseline_ub,
                         **res.stats}, self.name)

    def solve_batch(self, topos, dems) -> list[ThroughputResult]:
        _check_batch_lengths(topos, dems)
        return [self.solve(t, d) for t, d in zip(topos, dems)]


ENGINES: dict[str, Callable[[], ThroughputEngine]] = {
    "exact": ExactLPEngine,
    "dual": DualEngine,
    "dual-pallas": lambda **kw: DualEngine(use_pallas=True, **kw),
    "primal": PrimalEngine,
    "certified": CertifiedEngine,
    "ecmp": EcmpEngine,
    "ksp": KspEngine,
    "auto": AutoEngine,
    "adversarial": AdversarialEngine,
}


def get_engine(name: str, **kw) -> ThroughputEngine:
    """Instantiate a registered engine by name (kwargs go to its ctor)."""
    try:
        factory = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; known: {sorted(ENGINES)}") from None
    return factory(**kw) if kw else factory()


def as_engine(engine: str | ThroughputEngine) -> ThroughputEngine:
    """Accept an engine instance or a registry name (deprecation shim for
    the old ``engine: str`` plumbing)."""
    if isinstance(engine, str):
        return get_engine(engine)
    return engine


# ---------------------------------------------------------------------------
# declarative sweeps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One x of a sweep: throughput stats over the seeded runs, plus the
    certified bracket aggregates when the engine provides brackets
    (``lb_mean`` = mean certified lower bound, ``gap_max`` = worst
    relative bracket width (ub-lb)/ub across the runs; ``None`` on
    engines without brackets).  ``meta`` carries engine-specific
    aggregates requested via ``run_sweeps(..., meta_reduce=...)`` —
    e.g. the routing engines' ``ideal_gap_pct`` — and is empty when no
    reduction was requested."""

    x: float
    mean: float
    std: float
    values: tuple[float, ...]
    lb_mean: float | None = None
    gap_max: float | None = None
    meta: Mapping[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class Sweep:
    """One paper-style experiment: measure throughput at each ``x`` over
    ``runs`` seeded repetitions under a named traffic pattern."""

    xs: tuple[float, ...]
    runs: int = 3
    seed0: int = 0
    traffic: str = "permutation"
    traffic_kw: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def seeds(self) -> list[int]:
        return [self.seed0 + 1000 * rr for rr in range(self.runs)]


def run_sweeps(items: Sequence[tuple[Sweep, Callable[[float, int], Topology]]],
               engine: str | ThroughputEngine = "exact", *,
               meta_reduce: Mapping[str, Callable[[Sequence[float]], float]]
               | None = None) -> list[list[SweepPoint]]:
    """Run a whole family of sweeps through ONE ``solve_batch`` call.

    ``items`` is a sequence of ``(sweep, build_fn)`` pairs
    (``build_fn(x, seed) -> Topology``; the traffic pattern is drawn with
    seed ``seed + 1`` from each sweep's ``traffic``).  Every (sweep × x ×
    run) instance is built up front and solved in a single batch — on
    batching engines that is one ``BatchPlan`` spanning the entire figure
    family (Fig. 6's grid, Fig. 7's three panels, ...), so bucketing,
    chunking and device sharding see ALL the work at once.  Returns one
    ``list[SweepPoint]`` per input item, in order.

    ``meta_reduce`` maps engine-specific meta keys to reducers (e.g.
    ``{"ideal_gap_pct": max}``): each key present in EVERY run of a
    point is reduced over the point's runs into ``SweepPoint.meta``
    (keys missing from any run are skipped, so a reduction requested for
    one engine is harmless on another).  The built-in bracket aggregates
    (``lb_mean``/``gap_max``) are computed exactly as before, with or
    without the hook.
    """
    eng = as_engine(engine)
    topos, dems, spans = [], [], []
    for sweep, build_fn in items:
        start = len(topos)
        for x in sweep.xs:
            for seed in sweep.seeds():
                topo = build_fn(x, seed)
                dem = traffic_mod.make(sweep.traffic, topo.servers, seed + 1,
                                       **sweep.traffic_kw)
                topos.append(topo)
                dems.append(dem)
        spans.append(start)
    results = eng.solve_batch(topos, dems) if topos else []
    out: list[list[SweepPoint]] = []
    for (sweep, _), start in zip(items, spans):
        points = []
        for pi, x in enumerate(sweep.xs):
            lo = start + pi * sweep.runs
            rs = results[lo:lo + sweep.runs]
            vals = [r.throughput for r in rs]
            v = np.asarray(vals)
            # brackets ride along when every run of the point carries one
            lbs = [r.meta["lb"] for r in rs if "lb" in r.meta]
            gaps = [r.meta["gap"] for r in rs if "gap" in r.meta]
            bracketed = rs and len(lbs) == len(rs) and len(gaps) == len(rs)
            meta: dict[str, float] = {}
            for key, reduce_fn in (meta_reduce or {}).items():
                got = [r.meta[key] for r in rs if key in r.meta]
                if rs and len(got) == len(rs):
                    meta[key] = float(reduce_fn(got))
            points.append(SweepPoint(
                float(x), float(v.mean()), float(v.std()), tuple(vals),
                lb_mean=float(np.mean(lbs)) if bracketed else None,
                gap_max=float(max(gaps)) if bracketed else None,
                meta=meta))
        out.append(points)
    return out


def run_sweep(sweep: Sweep,
              build_fn: Callable[[float, int], Topology],
              engine: str | ThroughputEngine = "exact", *,
              meta_reduce: Mapping[str, Callable[[Sequence[float]], float]]
              | None = None) -> list[SweepPoint]:
    """Run one declarative sweep (``run_sweeps`` with a single item): every
    (x, run) instance goes through ONE ``solve_batch`` call; an empty
    ``sweep.xs`` returns ``[]``."""
    return run_sweeps([(sweep, build_fn)], engine,
                      meta_reduce=meta_reduce)[0]
