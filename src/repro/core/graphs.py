"""Topology generation for data center networks.

``Topology`` is the single currency of the repo: a dense symmetric capacity
matrix ``cap[N, N]`` (cap[u, v] = total link capacity u->v; 0 = no link;
multi-links between a switch pair sum their capacities), a ``servers[N]``
vector giving the number of attached servers per switch, and optional per-
switch class ``labels``.  Capacities are in units of the base line-speed
(1 unit = one 1GbE link); a 10GbE link contributes 10.

Every public generator returns a ``Topology``; the bare capacity-matrix
builders survive as private ``_*_cap`` helpers for callers that compose
matrices by hand.  Generation is plain numpy (paper-scale graphs are small);
the throughput engines (``repro.core.engine``) consume Topologies.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "Topology",
    "EllGraph",
    "as_cap",
    "connected_components",
    "degree_stats",
    "random_regular_graph",
    "random_graph_from_degrees",
    "random_regular_ell",
    "biased_two_cluster_graph",
    "power_law_degrees",
    "distribute_servers",
]

# non-edge sentinel of the padded-ELL export; numerically identical to
# ``repro.core.apsp._INF`` (this module stays numpy-pure / jax-free, so
# the constant is duplicated and pinned equal by a test)
_ELL_INF = 1.0e18


@dataclasses.dataclass(frozen=True)
class EllGraph:
    """A padded-ELL (fixed-width sparse) view of a weighted graph.

    Row ``v`` of ``(idx, wgt)`` lists ``v``'s neighbors ascending; unused
    slots pad the END of the row with ``idx = v`` (a safe self-gather)
    and ``wgt = _ELL_INF``.  This is the exact table layout
    ``repro.kernels.ell`` relaxes and ``repro.core.apsp._pack_ell``
    produces — for the symmetric capacity patterns ``Topology`` carries,
    the in- and out-neighbor sets coincide, so one table serves both
    orientations.  Shapes are static in ``d_max``, which is what lets
    the ``"ell-bf"`` backend jit, vmap, and AOT-cache cleanly."""

    idx: np.ndarray   # [N, d_max] int32 neighbor ids, pads = own row id
    wgt: np.ndarray   # [N, d_max] float32 lengths, pads = _ELL_INF

    @property
    def n(self) -> int:
        return int(self.idx.shape[0])

    @property
    def d_max(self) -> int:
        return int(self.idx.shape[1])

    def validate(self) -> None:
        assert self.idx.shape == self.wgt.shape and self.idx.ndim == 2
        assert self.idx.dtype == np.int32
        assert self.wgt.dtype == np.float32
        assert np.all((self.idx >= 0) & (self.idx < self.n))
        valid = self.wgt < _ELL_INF / 2
        # pads sit after every valid slot and self-reference their row
        assert np.all(valid[:, 1:] <= valid[:, :-1]), "pads must be last"
        rows = np.arange(self.n)[:, None]
        assert np.all(np.where(valid, True, self.idx == rows)), \
            "pad slots must self-reference"

    def to_dense(self) -> np.ndarray:
        """The dense length matrix this table packs: ``_ELL_INF``
        non-edges, zero diagonal (the ``apsp`` input convention)."""
        w = np.full((self.n, self.n), _ELL_INF, np.float32)
        valid = self.wgt < _ELL_INF / 2
        rows = np.repeat(np.arange(self.n), valid.sum(axis=1))
        w[self.idx[valid], rows] = self.wgt[valid]   # idx row = incoming
        np.fill_diagonal(w, 0.0)
        return w


def degree_stats(cap: "Topology | np.ndarray") -> tuple[int, float]:
    """Host-side density facts of a capacity pattern: ``(d_max,
    mean_degree)`` — max off-diagonal nonzero count over rows, and the
    mean over rows that have at least one edge (padded lanes in a solver
    batch are all-zero rows and must not dilute the density signal).
    Accepts one matrix or a stacked batch; this is what the solvers feed
    ``resolve_backend`` / the ``"ell-bf"`` ``d_max`` static."""
    cap = np.asarray(as_cap(cap))
    n = cap.shape[-1]
    deg = (cap > 0).sum(axis=-1) - (np.einsum("...ii->...i", cap) > 0)
    deg = deg.reshape(-1)
    live = deg > 0
    if not live.any():
        return 0, 0.0
    return int(deg.max()), float(deg[live].mean())


@dataclasses.dataclass(frozen=True)
class Topology:
    """A switch-level network: capacities + server attachment."""

    cap: np.ndarray        # [N, N] float, symmetric, zero diagonal
    servers: np.ndarray    # [N] int, servers attached to each switch
    labels: np.ndarray | None = None  # [N] int class label (e.g. 0=small, 1=large)
    # [N] bool, True = this node is an expanded server leaf (see
    # ``with_server_nodes``); None = a plain switch-level topology
    server_nodes: np.ndarray | None = None

    def __array__(self, dtype=None, copy=None):
        # lets np.asarray/np.stack treat a Topology as its capacity matrix
        return np.asarray(self.cap, dtype=dtype)

    @property
    def n(self) -> int:
        return int(self.cap.shape[0])

    @property
    def total_capacity(self) -> float:
        """Total capacity counting both directions (paper's C)."""
        return float(self.cap.sum())

    @property
    def num_servers(self) -> int:
        return int(self.servers.sum())

    def cut_capacity(self, mask: np.ndarray) -> float:
        """Capacity crossing the cut (both directions) for boolean mask."""
        m = np.asarray(mask, bool)
        return float(self.cap[m][:, ~m].sum() + self.cap[~m][:, m].sum())

    def validate(self) -> None:
        assert self.cap.shape[0] == self.cap.shape[1]
        assert np.allclose(self.cap, self.cap.T), "capacity matrix must be symmetric"
        assert np.all(np.diag(self.cap) == 0), "no self loops"
        assert np.all(self.cap >= 0)
        assert self.servers.shape == (self.n,)
        assert np.all(self.servers >= 0)
        if self.server_nodes is not None:
            assert self.server_nodes.shape == (self.n,)
            assert self.server_nodes.dtype == bool

    def degrade(self, link_mask: np.ndarray | None = None,
                dead_switches: Sequence[int] | np.ndarray | None = None
                ) -> "Topology":
        """A validated degraded copy of this topology (failure injection).

        ``link_mask``: [N, N] bool, True = the link survives; must be
        symmetric (a link fails in both directions — ``ValueError``
        otherwise).  ``dead_switches``: switch indices whose row/column is
        zeroed entirely and whose attached servers are stranded.

        Graceful-degradation semantics: servers on a dead switch — or on a
        switch left with zero surviving network capacity — are stranded and
        zeroed in ``servers`` (their demand cannot enter the network).  The
        node count never changes, so degraded variants of one topology all
        share a batch-plan bucket.  The result passes ``validate()``; the
        caller decides how to treat demand between the surviving-but-
        disconnected components (see ``repro.core.mcf.drop_disconnected``).
        """
        cap = self.cap.copy()
        servers = self.servers.copy()
        if link_mask is not None:
            m = np.asarray(link_mask, bool)
            if m.shape != cap.shape:
                raise ValueError(f"link_mask shape {m.shape} != capacity "
                                 f"shape {cap.shape}")
            if not np.array_equal(m, m.T):
                raise ValueError("link_mask must be symmetric: links fail "
                                 "in both directions")
            cap = np.where(m, cap, 0.0)
        if dead_switches is not None:
            dead = np.asarray(dead_switches, np.int64)
            if dead.size and (dead.min() < 0 or dead.max() >= self.n):
                raise ValueError(f"dead switch index out of range [0, "
                                 f"{self.n})")
            cap[dead, :] = 0.0
            cap[:, dead] = 0.0
            servers[dead] = 0
        servers[cap.sum(axis=1) == 0] = 0       # stranded: no surviving link
        out = Topology(cap=cap, servers=servers, labels=self.labels,
                       server_nodes=self.server_nodes)
        out.validate()
        return out

    def with_server_nodes(self, nic_capacity: float = 1.0) -> "Topology":
        """The server-expanded view of this switch-level topology.

        Each of the ``servers[i]`` servers of switch ``i`` becomes its own
        degree-1 leaf node linked to ``i`` with ``nic_capacity``.  Leaves
        are appended AFTER the switch block in ``np.repeat(arange(N),
        servers)`` order — the exact server enumeration
        ``repro.core.traffic`` uses, so a traffic pattern built from the
        expanded ``servers`` vector (one server per leaf) is the
        node-granular version of the same switch-level pattern.  The
        returned topology carries a ``server_nodes`` mask; ``coarsen``
        inverts the expansion exactly."""
        if self.server_nodes is not None:
            raise ValueError("topology is already server-expanded")
        if nic_capacity <= 0:
            raise ValueError(f"nic_capacity must be > 0, got {nic_capacity}")
        n, s = self.n, self.num_servers
        owner = np.repeat(np.arange(n), self.servers)
        m = n + s
        cap = np.zeros((m, m), dtype=np.float64)
        cap[:n, :n] = self.cap
        leaf = n + np.arange(s)
        cap[leaf, owner] = nic_capacity
        cap[owner, leaf] = nic_capacity
        servers = np.concatenate([np.zeros(n, np.int64),
                                  np.ones(s, np.int64)])
        labels = None
        if self.labels is not None:
            labels = np.concatenate([self.labels, self.labels[owner]])
        mask = np.concatenate([np.zeros(n, bool), np.ones(s, bool)])
        out = Topology(cap=cap, servers=servers, labels=labels,
                       server_nodes=mask)
        out.validate()
        return out

    def coarsen(self, dem: np.ndarray | None = None):
        """Contract the server leaves back onto their switches (the exact
        inverse of ``with_server_nodes``).

        Every ``server_nodes``-marked node must be a degree-1 leaf whose
        single link lands on a non-server node (``ValueError`` otherwise
        — contraction of anything else would change the flow problem).
        Its ``servers`` count folds into its switch; an optional node-
        level demand matrix is lifted by summing over each switch's
        leaves, with the diagonal zeroed (intra-switch traffic never
        enters the network — the same pairs switch-level traffic
        construction drops).

        Returns the switch-level ``Topology``, or ``(topology,
        lifted_dem)`` when ``dem`` is given.  A topology without server
        nodes passes through unchanged."""
        if self.server_nodes is None:
            return self if dem is None else (self, dem)
        srv = self.server_nodes
        sw = np.flatnonzero(~srv)
        leaves = np.flatnonzero(srv)
        deg = (self.cap[leaves] > 0).sum(axis=1)
        if np.any(deg != 1):
            bad = leaves[np.flatnonzero(deg != 1)[:5]]
            raise ValueError(f"server nodes {bad.tolist()} are not "
                             "degree-1 leaves; cannot coarsen")
        owner = np.argmax(self.cap[leaves] > 0, axis=1)
        if np.any(srv[owner]):
            bad = leaves[np.flatnonzero(srv[owner])[:5]]
            raise ValueError(f"server nodes {bad.tolist()} attach to "
                             "another server node; cannot coarsen")
        # coarse index of every node: switches keep their relative order
        coarse = np.full(self.n, -1, np.int64)
        coarse[sw] = np.arange(len(sw))
        servers = self.servers[sw].copy()
        np.add.at(servers, coarse[owner], self.servers[leaves])
        labels = self.labels[sw] if self.labels is not None else None
        topo = Topology(cap=self.cap[np.ix_(sw, sw)], servers=servers,
                        labels=labels)
        topo.validate()
        if dem is None:
            return topo
        dem = np.asarray(dem, np.float64)
        if dem.shape != (self.n, self.n):
            raise ValueError(f"demand shape {dem.shape} != node count "
                             f"({self.n}, {self.n})")
        node_to = coarse.copy()
        node_to[leaves] = coarse[owner]
        lifted = np.zeros((len(sw), len(sw)), np.float64)
        np.add.at(lifted, (node_to[:, None], node_to[None, :]), dem)
        np.fill_diagonal(lifted, 0.0)
        return topo, lifted

    def to_ell(self, d_max: int | None = None,
               lengths: np.ndarray | None = None) -> "EllGraph":
        """Export the link pattern as a padded-ELL table (``EllGraph``).

        ``lengths`` gives per-link lengths (defaults to unit hops — the
        ASPL / frontier-probe metric); only its entries on the nonzero
        capacity pattern are read.  ``d_max`` sets the table width:
        defaults to the actual max degree, and a value below it raises
        (silent truncation would drop edges).  Neighbor ids ascend
        within each row; pads self-reference with ``_ELL_INF`` weight."""
        adj = self.cap > 0
        np.fill_diagonal(adj, False)
        deg = adj.sum(axis=1)
        actual = int(deg.max()) if self.n else 0
        if d_max is None:
            d_max = max(actual, 1)
        elif d_max < actual:
            raise ValueError(f"d_max={d_max} < max degree {actual}: the "
                             "padded-ELL table would silently drop edges")
        if lengths is None:
            lengths = np.ones_like(self.cap, dtype=np.float32)
        else:
            lengths = np.asarray(lengths, np.float32)
            if lengths.shape != self.cap.shape:
                raise ValueError(f"lengths shape {lengths.shape} != "
                                 f"capacity shape {self.cap.shape}")
        idx = np.tile(np.arange(self.n, dtype=np.int32)[:, None],
                      (1, d_max))
        wgt = np.full((self.n, d_max), _ELL_INF, np.float32)
        # row-major nonzero enumeration is ascending within each row
        rows, cols = np.nonzero(adj)
        slot = np.arange(len(rows)) - np.searchsorted(rows, rows)
        idx[rows, slot] = cols.astype(np.int32)
        wgt[rows, slot] = lengths[cols, rows]   # incoming: w(col -> row)
        out = EllGraph(idx=idx, wgt=wgt)
        out.validate()
        return out


def as_cap(topo: Topology | np.ndarray) -> np.ndarray:
    """Coerce a Topology or a bare capacity matrix to an [N, N] float array."""
    if isinstance(topo, Topology):
        return topo.cap
    return np.asarray(topo, dtype=np.float64)


def connected_components(topo: Topology | np.ndarray) -> np.ndarray:
    """[N] int component label per switch (equal label = a path exists).

    Plain BFS over the nonzero pattern of the (symmetric) capacity matrix —
    the cheap host-side reachability check failure handling is built on: a
    demanded pair is routable iff its endpoints share a label."""
    adj = as_cap(topo) > 0
    n = adj.shape[0]
    labels = np.full(n, -1, np.int64)
    comp = 0
    for start in range(n):
        if labels[start] >= 0:
            continue
        frontier = np.zeros(n, bool)
        frontier[start] = True
        member = frontier.copy()
        while frontier.any():
            frontier = (adj[frontier].any(axis=0)) & ~member
            member |= frontier
        labels[member] = comp
        comp += 1
    return labels


def _servers_vec(servers: int | Sequence[int], n: int) -> np.ndarray:
    srv = np.asarray(servers, dtype=np.int64)
    if srv.ndim == 0:
        srv = np.full(n, int(srv), dtype=np.int64)
    if srv.shape != (n,):
        raise ValueError(f"servers must be a scalar or a length-{n} vector")
    return srv


def _pair_stubs(stubs_a: np.ndarray, stubs_b: np.ndarray | None,
                rng: np.random.Generator) -> np.ndarray:
    """Randomly pair stubs.  If stubs_b is None pair within stubs_a,
    else pair each of stubs_a with one of stubs_b (bipartite).
    Returns an array of (u, v) pairs (may contain self loops / multi-edges;
    caller repairs)."""
    if stubs_b is None:
        s = rng.permutation(stubs_a)
        half = len(s) // 2
        return np.stack([s[:half], s[half: 2 * half]], axis=1)
    a = rng.permutation(stubs_a)
    b = rng.permutation(stubs_b)
    k = min(len(a), len(b))
    return np.stack([a[:k], b[:k]], axis=1)


def _repair_multigraph(adj: np.ndarray, rng: np.random.Generator,
                       max_iter: int = 4_000) -> np.ndarray:
    """Remove self-loops and multi-edges by double-edge swaps, preserving the
    degree sequence.  ``adj`` is an integer multi-adjacency matrix."""
    adj = adj.copy()
    for _ in range(max_iter):
        bad_self = np.flatnonzero(np.diag(adj) > 0)
        multi = np.argwhere(np.triu(adj, 1) > 1)
        if len(bad_self) == 0 and len(multi) == 0:
            return adj
        # pick one offending placement
        if len(bad_self) > 0:
            u, v = int(bad_self[0]), int(bad_self[0])
        else:
            u, v = int(multi[0][0]), int(multi[0][1])
        # pick a random other edge (x, y) and swap: (u,v),(x,y) -> (u,x),(v,y)
        xs, ys = np.nonzero(np.triu(adj, 0))
        if len(xs) == 0:
            break
        for _try in range(200):
            i = int(rng.integers(len(xs)))
            x, y = int(xs[i]), int(ys[i])
            if rng.random() < 0.5:
                x, y = y, x
            if len({u, v, x, y}) < (3 if u == v else 4):
                continue
            # would the swap introduce new conflicts? allow reductions only
            if adj[u, x] > 0 or adj[v, y] > 0 or u == x or v == y:
                continue
            adj[u, v] -= 1
            adj[v, u] -= 1
            adj[x, y] -= 1
            adj[y, x] -= 1
            adj[u, x] += 1
            adj[x, u] += 1
            adj[v, y] += 1
            adj[y, v] += 1
            break
        else:
            # reshuffle failure: give up this offender ordering; try again
            continue
    raise RuntimeError("could not repair multigraph into a simple graph")


def random_graph_from_degrees(degrees: Sequence[int], seed: int,
                              capacity: float = 1.0,
                              allow_multi: bool = False,
                              servers: int | Sequence[int] = 0) -> Topology:
    """Sample a (near-)uniform simple graph with the given degree sequence via
    the configuration model with double-edge-swap repair (the Jellyfish
    construction).  ``servers`` attaches that many servers per switch (scalar)
    or per-switch counts (vector).

    ``allow_multi=True`` keeps parallel edges (their capacities sum) and only
    repairs self-loops — used for fabrics whose degree sequence is not
    graphical as a simple graph (parallel links are physically fine)."""
    cap = _random_graph_cap(degrees, seed, capacity, allow_multi)
    return Topology(cap=cap, servers=_servers_vec(servers, len(cap)))


def _random_graph_cap(degrees: Sequence[int], seed: int,
                      capacity: float = 1.0,
                      allow_multi: bool = False) -> np.ndarray:
    """Bare-matrix variant of ``random_graph_from_degrees``."""
    degrees = np.asarray(degrees, dtype=np.int64)
    n = len(degrees)
    if degrees.sum() % 2 != 0:
        raise ValueError("degree sum must be even")
    for attempt in range(4):
        rng = np.random.default_rng(seed + 7919 * attempt)
        stubs = np.repeat(np.arange(n), degrees)
        pairs = _pair_stubs(stubs, None, rng)
        adj = np.zeros((n, n), dtype=np.int64)
        np.add.at(adj, (pairs[:, 0], pairs[:, 1]), 1)
        np.add.at(adj, (pairs[:, 1], pairs[:, 0]), 1)
        try:
            if allow_multi:
                adj = _repair_self_loops(adj, rng)
            else:
                adj = _repair_multigraph(adj, rng)
            return adj.astype(np.float64) * capacity
        except RuntimeError:
            if attempt == 3:
                # near-non-graphical sequence: fall back to parallel links
                # (physically valid — capacities sum) rather than failing
                adj = _repair_self_loops(adj, rng)
                return adj.astype(np.float64) * capacity
    raise AssertionError("unreachable")


def _repair_self_loops(adj: np.ndarray, rng: np.random.Generator,
                       max_iter: int = 20_000) -> np.ndarray:
    """Remove self-loops only (multi-edges allowed), preserving degrees: swap
    the loop (u,u) with a random edge (x,y), u != x,y -> (u,x),(u,y)."""
    adj = adj.copy()
    for _ in range(max_iter):
        loops = np.flatnonzero(np.diag(adj) > 0)
        if len(loops) == 0:
            return adj
        u = int(loops[0])
        xs, ys = np.nonzero(np.triu(adj, 1))
        cand = [(x, y) for x, y in zip(xs, ys) if x != u and y != u]
        if not cand:
            # degenerate: all edges touch u; drop the loop (2 ports unused)
            adj[u, u] -= 2
            continue
        x, y = cand[int(rng.integers(len(cand)))]
        adj[u, u] -= 2
        adj[x, y] -= 1
        adj[y, x] -= 1
        adj[u, x] += 1
        adj[x, u] += 1
        adj[u, y] += 1
        adj[y, u] += 1
    raise RuntimeError("could not remove self-loops")


def random_regular_graph(n: int, r: int, seed: int, capacity: float = 1.0,
                         servers: int | Sequence[int] = 0) -> Topology:
    """RRG(n, r): r-regular simple graph on n nodes."""
    cap = _random_regular_cap(n, r, seed, capacity)
    return Topology(cap=cap, servers=_servers_vec(servers, n))


def _random_regular_cap(n: int, r: int, seed: int,
                        capacity: float = 1.0) -> np.ndarray:
    """Bare-matrix variant of ``random_regular_graph``."""
    if n * r % 2 != 0:
        raise ValueError("n*r must be even")
    if r >= n:
        raise ValueError("need r < n")
    return _random_graph_cap([r] * n, seed, capacity)


def random_regular_ell(n: int, r: int, seed: int) -> EllGraph:
    """A degree-(<= r) random regular unit-length graph DIRECTLY in
    padded-ELL form — never materializes the dense matrix, which is the
    point: at N=16384 the dense float32 pattern alone is 1 GB, more than
    the whole streamed APSP budget.

    Construction: a ring (connectivity) unioned with ``r/2 - 1`` random
    permutation cycles, deduped — the standard sparse stand-in for the
    configuration-model RRG (same degree bound, same O(log N) diameter
    regime as Jellyfish graphs).  ``r`` must be even so the cycle union
    respects the degree bound.  Frontier probes in
    ``benchmarks/scale_bench.py`` are built here."""
    if r < 2 or r % 2:
        raise ValueError(f"r must be even and >= 2, got {r}")
    if r >= n:
        raise ValueError("need r < n")
    rng = np.random.default_rng(seed)
    nbrs = [set() for _ in range(n)]

    def add(u: int, v: int) -> None:
        if u != v:
            nbrs[u].add(v)
            nbrs[v].add(u)

    for i in range(n):
        add(i, (i + 1) % n)
    for _ in range(r // 2 - 1):
        perm = rng.permutation(n)
        for i in range(n):
            add(int(perm[i]), int(perm[(i + 1) % n]))
    d_max = max(len(s) for s in nbrs)
    idx = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, d_max))
    wgt = np.full((n, d_max), _ELL_INF, np.float32)
    for v, s in enumerate(nbrs):
        js = sorted(s)
        idx[v, :len(js)] = js
        wgt[v, :len(js)] = 1.0
    out = EllGraph(idx=idx, wgt=wgt)
    out.validate()
    return out


def biased_two_cluster_graph(
    deg_a: Sequence[int],
    deg_b: Sequence[int],
    cross_bias: float,
    seed: int,
    capacity: float = 1.0,
    servers: int | Sequence[int] = 0,
) -> Topology:
    """Two clusters of switches with network degrees ``deg_a`` / ``deg_b``.

    ``cross_bias`` scales the number of cross-cluster edges relative to the
    *expected* number under an unbiased (configuration-model) random graph,
    matching the x-axis normalisation of Figs. 5-7 in the paper.
    ``cross_bias=1`` recovers the vanilla random construction.

    Returns a Topology with labels 0 for cluster A, 1 for cluster B.
    """
    cap, labels = _biased_two_cluster_cap(deg_a, deg_b, cross_bias, seed,
                                          capacity)
    return Topology(cap=cap, servers=_servers_vec(servers, len(cap)),
                    labels=labels)


def _biased_two_cluster_cap(
    deg_a: Sequence[int],
    deg_b: Sequence[int],
    cross_bias: float,
    seed: int,
    capacity: float = 1.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Bare-matrix variant of ``biased_two_cluster_graph``:
    returns (cap[N,N], labels[N])."""
    deg_a = np.asarray(deg_a, dtype=np.int64)
    deg_b = np.asarray(deg_b, dtype=np.int64)
    na, nb = len(deg_a), len(deg_b)
    n = na + nb
    sa, sb = int(deg_a.sum()), int(deg_b.sum())
    s_tot = sa + sb
    if sa % 2 != sb % 2:
        # (sa - n_cross) and (sb - n_cross) always share n_cross's parity
        # flip, so no n_cross leaves both clusters' leftover stub counts
        # even — the old ±1 fixup loop below would never terminate.
        raise ValueError(
            f"cluster stub counts have different parity (sum(deg_a)={sa}, "
            f"sum(deg_b)={sb}); the total stub count must be even and both "
            "cluster degree sums must have the same parity — adjust "
            "deg_a/deg_b")
    rng = np.random.default_rng(seed)

    # expected cross edges under the unbiased configuration model
    exp_cross = sa * sb / max(s_tot - 1, 1)
    n_cross = int(round(cross_bias * exp_cross))
    n_cross = max(0, min(n_cross, min(sa, sb)))
    # parity: remaining stubs inside each cluster must be even (same-parity
    # sums guarantee this resolves in at most one ±1 step)
    while (sa - n_cross) % 2 != 0 or (sb - n_cross) % 2 != 0:
        n_cross += 1 if n_cross < min(sa, sb) else -1

    stubs_a = np.repeat(np.arange(na), deg_a)
    stubs_b = np.repeat(np.arange(nb), deg_b) + na
    stubs_a = rng.permutation(stubs_a)
    stubs_b = rng.permutation(stubs_b)

    pairs = []
    pairs.append(np.stack([stubs_a[:n_cross], stubs_b[:n_cross]], axis=1))
    rest_a = stubs_a[n_cross:]
    rest_b = stubs_b[n_cross:]
    if len(rest_a) >= 2:
        pairs.append(_pair_stubs(rest_a, None, rng))
    if len(rest_b) >= 2:
        pairs.append(_pair_stubs(rest_b, None, rng))
    pairs = np.concatenate([p for p in pairs if len(p)], axis=0)

    adj = np.zeros((n, n), dtype=np.int64)
    np.add.at(adj, (pairs[:, 0], pairs[:, 1]), 1)
    np.add.at(adj, (pairs[:, 1], pairs[:, 0]), 1)
    adj = _repair_two_cluster(adj, na, rng)
    labels = np.concatenate([np.zeros(na, np.int64), np.ones(nb, np.int64)])
    return adj.astype(np.float64) * capacity, labels


def _repair_two_cluster(adj: np.ndarray, na: int, rng: np.random.Generator,
                        max_iter: int = 20_000) -> np.ndarray:
    """Like _repair_multigraph but swaps only with a partner edge of the same
    class (intra-A / intra-B / cross), with the swap oriented so every new
    edge stays in-class — the cross-cluster edge count is preserved exactly.

    * intra offender (u,v) + intra partner (x,y):  -> (u,x),(v,y)
    * cross offender (a1,b1) + cross partner (a2,b2) with a in A, b in B:
                                                   -> (a1,b2),(a2,b1)
    Self-loops only ever occur inside a cluster (a cross pairing has distinct
    endpoints by construction)."""
    adj = adj.copy()

    def is_cross(u, v):
        return (u < na) != (v < na)

    # stall detection: when no swap reduces the offender count for a whole
    # window (a cluster too dense to be simple), jump straight to the
    # multi-edge fallback below instead of burning the full budget — the
    # designer's bias-perturbation moves probe exactly such corners and a
    # hopeless repair here used to cost seconds per candidate
    best_bad = np.inf
    stall = 0
    for _ in range(max_iter):
        bad_self = np.flatnonzero(np.diag(adj) > 0)
        multi = np.argwhere(np.triu(adj, 1) > 1)
        if len(bad_self) == 0 and len(multi) == 0:
            return adj
        bad = len(bad_self) + len(multi)
        if bad < best_bad:
            best_bad, stall = bad, 0
        else:
            stall += 1
            if stall > 200:
                break
        if len(bad_self) > 0:
            i = int(rng.integers(len(bad_self)))
            u = v = int(bad_self[i])
        else:
            i = int(rng.integers(len(multi)))
            u, v = int(multi[i][0]), int(multi[i][1])
        cross = is_cross(u, v)
        xs, ys = np.nonzero(np.triu(adj, 1) if cross else adj)
        # candidate partners of the same class — for intra offenders the
        # partner must be in the SAME cluster (an other-cluster intra swap
        # would mint two cross edges and break the bias semantics)
        same = [(int(x), int(y)) for x, y in zip(xs, ys)
                if is_cross(x, y) == cross
                and (cross or (x < na) == (u < na))]
        rng.shuffle(same)
        for x, y in same[:600]:
            if cross:
                a1, b1 = (u, v) if u < na else (v, u)
                a2, b2 = (x, y) if x < na else (y, x)
                if a1 == a2 or b1 == b2:
                    continue
                if adj[a1, b2] > 0 or adj[a2, b1] > 0:
                    continue
                new_edges = ((a1, b2), (a2, b1))
                old_edges = ((a1, b1), (a2, b2))
            else:
                if len({u, v, x, y}) < (3 if u == v else 4):
                    continue
                if u == x or v == y or adj[u, x] > 0 or adj[v, y] > 0:
                    continue
                if u == v and (adj[u, y] > 0 or x == y):
                    # self-loop (u,u) + (x,y) -> (u,x),(u,y)
                    continue
                if u == v:
                    new_edges = ((u, x), (u, y))
                else:
                    new_edges = ((u, x), (v, y))
                old_edges = ((u, v), (x, y))
            for (p, q) in old_edges:
                adj[p, q] -= 1
                if p != q:
                    adj[q, p] -= 1
                else:
                    adj[p, q] -= 1          # a self-loop uses two stubs
            for (p, q) in new_edges:
                adj[p, q] += 1
                adj[q, p] += 1
            break
    # iteration budget exhausted: a cluster may be too dense for a simple
    # graph (e.g. strongly-biased intra wiring).  Keep the remaining
    # multi-edges as parallel links (capacities sum — physically valid) and
    # retire leftover self-loop ports.
    loops = np.flatnonzero(np.diag(adj) > 0)
    for u in loops:
        adj[u, u] = 0
    return adj


def power_law_degrees(n: int, k_min: int, k_max: int, alpha: float,
                      seed: int) -> np.ndarray:
    """Port counts following a (discretised, truncated) power law
    P(k) ~ k^-alpha on [k_min, k_max] (paper Fig. 4 setup).  ``k_min ==
    k_max`` degenerates to a constant draw; an empty or inverted range
    raises ``ValueError``."""
    if k_min < 1:
        raise ValueError(f"k_min must be >= 1, got {k_min} (a switch needs "
                         "at least one port)")
    if k_max < k_min:
        raise ValueError(f"empty degree range: k_min={k_min} > k_max={k_max}")
    rng = np.random.default_rng(seed)
    ks = np.arange(k_min, k_max + 1, dtype=np.float64)
    p = ks ** (-alpha)
    p /= p.sum()
    return rng.choice(ks.astype(np.int64), size=n, p=p)


def distribute_servers(port_counts: Sequence[int], num_servers: int,
                       beta: float = 1.0) -> np.ndarray:
    """Distribute ``num_servers`` across switches in proportion to
    ``port_count**beta`` (paper Fig. 4), largest-remainder rounding, capped at
    port_count - 1 so every switch keeps at least one network port.

    Edge cases are pinned (expansion steps start from tiny pools):
    ``num_servers == 0`` returns all zeros, fewer servers than switches
    distributes without silent loss, and an empty pool (or a negative
    count) raises instead of returning a bad vector."""
    k = np.asarray(port_counts, dtype=np.float64)
    if num_servers < 0:
        raise ValueError(f"num_servers must be >= 0, got {num_servers}")
    if len(k) == 0:
        if num_servers == 0:
            return np.zeros(0, np.int64)
        raise ValueError("cannot distribute servers over an empty switch "
                         "pool")
    if num_servers == 0:
        return np.zeros(len(k), np.int64)
    w = k ** beta
    ideal = num_servers * w / w.sum()
    base = np.floor(ideal).astype(np.int64)
    rem = num_servers - int(base.sum())
    if rem > 0:
        order = np.argsort(-(ideal - base))
        base[order[:rem]] += 1
    # cap: leave >= 1 network port per switch, reassign overflow greedily
    cap_limit = np.asarray(port_counts, np.int64) - 1
    overflow = np.maximum(base - cap_limit, 0).sum()
    base = np.minimum(base, cap_limit)
    while overflow > 0:
        room = cap_limit - base
        i = int(np.argmax(room))
        if room[i] <= 0:
            raise ValueError("not enough ports for the requested servers")
        take = int(min(overflow, room[i]))
        base[i] += take
        overflow -= take
    return base
