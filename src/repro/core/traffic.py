"""Traffic matrices (paper §3, §8.1).

All traffic is specified at server level and aggregated to a switch-level
demand matrix ``dem[N, N]`` where dem[u, v] = number of unit-demand server
flows from switch u to switch v.  Flows between servers on the same switch
never enter the network and are dropped (they trivially achieve any
throughput).  Network throughput is the max θ such that every flow can be
routed at rate θ (max concurrent flow).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "make",
    "PATTERNS",
    "random_permutation",
    "all_to_all",
    "all_to_one",
    "stride",
    "adversarial",
    "num_flows",
]

# sub-stream keying: patterns that need a second independent RNG stream
# derive it as default_rng((seed, _KEY)) — a SeedSequence over (seed, key)
# — instead of ``seed + 1``, which collides with a caller sweeping
# consecutive seeds (seed=k's sub-stream == seed=k+1's main stream).
_STRIDE_REST_KEY = int.from_bytes(b"stride-rest", "little")


def _aggregate(src_sw: np.ndarray, dst_sw: np.ndarray, n: int) -> np.ndarray:
    dem = np.zeros((n, n), dtype=np.float64)
    keep = src_sw != dst_sw
    np.add.at(dem, (src_sw[keep], dst_sw[keep]), 1.0)
    return dem


def random_permutation(servers: np.ndarray, seed: int) -> np.ndarray:
    """Each server sends to exactly one other server and receives from exactly
    one (a random derangement over servers).

    A derangement needs at least two servers; fewer raise ``ValueError``
    (the old code silently fell out of its fixup loop on ``sum(servers) <
    2`` and returned an all-zero demand matrix, which downstream solvers
    reject with far more confusing errors).
    """
    servers = np.asarray(servers, np.int64)
    n = len(servers)
    s = int(servers.sum())
    if s < 2:
        raise ValueError(
            f"random_permutation needs >= 2 servers total, got {s} "
            "(a derangement over fewer servers does not exist)")
    sw_of_server = np.repeat(np.arange(n), servers)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(s)
    # derangement-ify: cycle the fixed points among themselves (one pass),
    # or swap a lone fixed point with a neighbour.  For s >= 2 each pass
    # strictly clears every current fixed point without creating new ones
    # among them, so this terminates in a handful of iterations; the cap
    # is a belt-and-braces guard that now FAILS LOUDLY instead of
    # returning a non-derangement.
    for _ in range(100):
        fixed = np.flatnonzero(perm == np.arange(s))
        if len(fixed) == 0:
            break
        if len(fixed) == 1:
            j = (fixed[0] + 1) % s
            perm[fixed[0]], perm[j] = perm[j], perm[fixed[0]]
        else:
            perm[fixed] = perm[np.roll(fixed, 1)]
    if (perm == np.arange(s)).any():
        raise RuntimeError(
            "random_permutation failed to build a derangement in 100 "
            f"fixup passes (s={s}, seed={seed}); this should be impossible "
            "for s >= 2 — please report")
    return _aggregate(sw_of_server, sw_of_server[perm], n)


def all_to_all(servers: np.ndarray) -> np.ndarray:
    """Every server sends one unit flow to every other server."""
    servers = np.asarray(servers, np.float64)
    dem = np.outer(servers, servers)
    np.fill_diagonal(dem, 0.0)
    return dem


def all_to_one(servers: np.ndarray, seed: int) -> np.ndarray:
    """Every server sends to one random server (paper §8.1(b)).

    The target switch is drawn server-weighted among switches that HAVE
    servers; a fleet with no servers (or with every server on one switch,
    so no flow could ever cross the network) raises ``ValueError`` instead
    of dividing by zero / returning an all-zero demand matrix that
    downstream solvers reject with far more confusing errors.
    """
    servers = np.asarray(servers, np.int64)
    n = len(servers)
    total = int(servers.sum())
    if total == 0:
        raise ValueError(
            "all_to_one needs >= 1 server, got 0 (no sender, no target)")
    occupied = np.flatnonzero(servers > 0)
    if len(occupied) < 2:
        raise ValueError(
            "all_to_one needs servers on >= 2 switches, got "
            f"{len(occupied)} (all traffic would stay on-switch and the "
            "demand matrix would be all-zero)")
    rng = np.random.default_rng(seed)
    target_sw = int(rng.choice(occupied, p=servers[occupied] / total))
    dem = np.zeros((n, n), np.float64)
    dem[:, target_sw] = servers
    dem[target_sw, target_sw] = 0.0
    return dem


def stride(servers: np.ndarray, frac: float, seed: int) -> np.ndarray:
    """x% Stride (paper §8.1(c)): a fraction ``frac`` of switches (ToRs) engage
    in a ToR-level permutation — each sends *all* its servers' traffic to one
    other ToR in the set; the rest run a server-level random permutation among
    themselves.

    ``frac`` must lie in [0, 1] — out-of-range values used to crash deep
    inside ``rng.choice`` with an opaque numpy error (k > n)."""
    if not 0.0 <= frac <= 1.0:
        raise ValueError(
            f"stride frac must be in [0, 1], got {frac!r} (the fraction "
            "of switches engaging in the ToR-level permutation)")
    servers = np.asarray(servers, np.int64)
    n = len(servers)
    rng = np.random.default_rng(seed)
    k = int(round(frac * n))
    stride_sw = rng.choice(n, size=k, replace=False)
    dem = np.zeros((n, n), np.float64)
    if k >= 2:
        p = rng.permutation(stride_sw)        # ToR-level cycle p0->p1->...->p0
        for u, v in zip(p, np.roll(p, -1)):
            dem[u, v] += servers[u]
    rest = np.setdiff1d(np.arange(n), stride_sw)
    if len(rest) >= 2 and servers[rest].sum() >= 2:
        # independent sub-stream (NOT seed + 1, which would alias the
        # server-level permutation of the next seed in a seed sweep)
        sub = random_permutation(servers[rest], (seed, _STRIDE_REST_KEY))
        dem[np.ix_(rest, rest)] += sub
    return dem


def adversarial(servers: np.ndarray, seed: int, *, topo=None,
                **search_kw) -> np.ndarray:
    """Near-worst-case hose-feasible demand matrix for a SPECIFIC topology.

    Unlike every other pattern, adversarial traffic is a property of the
    (topology, servers) pair, not of ``servers`` alone: the worst TM is
    found by gradient descent ON throughput through the differentiable
    dual solve (``repro.core.adversarial.find_worst_tm``).  Pass the
    topology via the ``topo=`` keyword; ``search_kw`` forwards the search
    knobs (rounds / candidates / iters / ...).  Raises ``ValueError``
    without a topology — there is no topology-free worst case.
    """
    if topo is None:
        raise ValueError(
            "traffic pattern 'adversarial' needs the topology it attacks: "
            "traffic.make('adversarial', servers, seed, topo=topo).  The "
            "worst-case TM is a property of the wiring, not of the server "
            "counts alone.")
    from repro.core.adversarial import find_worst_tm   # lazy: avoid cycle
    return find_worst_tm(topo, seed=seed, **search_kw).tm


def num_flows(dem: np.ndarray) -> float:
    """Number of (unit-demand) flows in the demand matrix."""
    return float(dem.sum())


# --- named pattern registry -------------------------------------------------
# Every entry has the uniform signature (servers, seed, **pattern_kw) ->
# dem[N, N] so sweep drivers can stay pattern-agnostic; unknown keyword
# arguments raise TypeError rather than being silently ignored.
# Deterministic patterns ignore the seed.  "adversarial" additionally
# needs the topology it attacks (kw: ``topo=``) — see ``adversarial``.
PATTERNS = {
    "permutation": lambda servers, seed: random_permutation(servers, seed),
    "all_to_all": lambda servers, seed: all_to_all(servers),
    "all_to_one": lambda servers, seed: all_to_one(servers, seed),
    "stride": lambda servers, seed, frac=1.0: stride(servers, frac, seed),
    "adversarial": lambda servers, seed, **kw: adversarial(servers, seed,
                                                           **kw),
}


def make(name: str, servers: np.ndarray, seed: int = 0, **kw) -> np.ndarray:
    """Build the named traffic pattern's switch-level demand matrix.

    Known names: permutation, all_to_all, all_to_one, stride (kw:
    ``frac``), adversarial (kw: ``topo`` + search knobs).
    """
    try:
        fn = PATTERNS[name]
    except KeyError:
        raise ValueError(
            f"unknown traffic pattern {name!r}; known: {sorted(PATTERNS)}"
        ) from None
    return fn(np.asarray(servers, np.int64), seed, **kw)
