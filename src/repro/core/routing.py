"""Routing-restricted throughput: ECMP and k-shortest-path lower bounds.

Every other engine in the repo scores a topology by ideal max-concurrent
flow — the fluid optimum any routing could at best achieve.  Real fabrics
route over restricted path sets, and the gap matters: Jellyfish (arXiv
1110.1687) made exactly this point by evaluating random graphs under
k-shortest-path routing with multipath transport, where ECMP's
shortest-only splitting strands a large fraction of the fluid capacity.
This module scores that deployable throughput as two certified LOWER
bounds on θ*, both driven by the same converged (min,+) APSP machinery
as the ideal solvers:

* **ECMP** (``solve_ecmp_batch``): split every demand equally over its
  equal-cost next hops — the SP-DAG membership test
  ``dist[v, t] == 1 + dist[u, t]`` on unit-hop APSP distances.  The
  split is a *linear* operator that strictly decreases distance-to-go,
  so one ``hops``-step fixed-point evaluation (no descent) yields the
  exact ECMP loads; ``1 / max_utilization`` is then a certified lower
  bound carried by an explicit feasible routing.
* **KSP** (``solve_ksp_batch``): restrict each pair to its k shortest
  simple paths (``repro.kernels.paths``, a static ``[pairs, k,
  max_hops + 1]`` tensor enumerated host-side at pack time) and optimise
  the per-pair split with multiplicative weights — softmax logits per
  (pair, path), Adam on a smoothed max-utilization (temperature-scaled
  logsumexp), the same cosine-decayed Adam + ``check_every``/``tol``
  early-stop + ``n_valid`` masking discipline as ``mcf.solve_dual_batch``.
  Every iterate's *exact* (unsmoothed) utilization certifies
  ``1 / umax``, so the running best is always a true lower bound.

**The ordering lattice.**  Both solvers also run the dual descent
(``mcf._descend``) in the same fused program, so every result carries
the ideal upper bound for free and the engines report
``meta["ideal_gap_pct"]`` — the certified price of the routing
restriction.  The KSP program additionally evaluates the ECMP operating
point (sharing its unit-hop APSP) and floors its bound with it: a
k-path multipath deployment never reports below the equal-split
baseline it deviates from.  That makes the bound ordering

    ``ecmp  <=  ksp(k)  <=  theta_exact  <=  dual ub``

mechanical on every instance — each step certified, none statistical.
(Jellyfish's measurement is the strict version of the first
inequality: KSP with enough paths recovers most of what ECMP leaves
behind.)  ``tests/test_conformance.py`` pins the full lattice across
all traffic patterns x graph families, and monotonicity in k against a
scipy ``linprog`` path-LP cross-check (``path_lp_throughput``).

Batching, padding, donation, sharding and AOT mirror ``primal``/``mcf``
exactly, so ``get_engine("ecmp")`` / ``get_engine("ksp")`` run whole
sweep families through ONE ``BatchPlan.execute`` with ``refill`` reuse
(``solver="ecmp"`` / ``"ksp"`` in ``plan.SOLVERS``).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.apsp import normalize_backend
from repro.core.graphs import Topology, as_cap
from repro.core.mcf import (_INF, _descend, apsp, jit_cache_size,
                            resolve_backend_density)
from repro.kernels import ops as kops
from repro.kernels import paths as kpaths

__all__ = ["RoutingResult", "RoutingBatchResult", "solve_ecmp",
           "solve_ecmp_batch", "solve_ksp", "solve_ksp_batch",
           "path_lp_throughput", "compile_cache_sizes",
           "DEFAULT_K", "DEFAULT_MAX_HOPS"]

DEFAULT_K = 8          # path-set width: Jellyfish's evaluation sweet spot
DEFAULT_MAX_HOPS = 12  # per-path hop budget for the static path tensor
_MW_BETA = 32.0        # logsumexp sharpness of the smoothed max-utilization


@dataclasses.dataclass(frozen=True)
class RoutingResult:
    """One instance's routing-restricted solve: a certified LOWER bound
    on θ* under the routing restriction (an explicit feasible routing
    achieves it) plus the ideal dual descent's free UPPER bound, whose
    ratio is the certified price of the restriction."""

    throughput_lb: float      # certified routed lower bound
    throughput_ub: float      # ideal dual bound from the fused descent
    final_util: float         # max edge utilization of the final routing
    iterations: int           # optimisation steps executed (0 for pure ECMP)

    @property
    def gap(self) -> float:
        """Relative ideal-vs-routed gap (ub - lb) / ub."""
        return (self.throughput_ub - self.throughput_lb) / \
            max(self.throughput_ub, 1e-30)


@dataclasses.dataclass(frozen=True)
class RoutingBatchResult:
    """Per-instance outputs of one batched routing solve.  Indexing and
    iteration yield the certified lower bounds (``throughput_lb``); a
    ``block=False`` solve carries in-flight ``jax.Array``s (sync with
    ``jax.block_until_ready``)."""

    throughput_lb: np.ndarray   # [B] certified routed lower bound
    throughput_ub: np.ndarray   # [B] ideal dual bound (free)
    final_util: np.ndarray      # [B] max utilization of the final routing
    iterations: np.ndarray      # [B] optimisation steps per instance

    def __len__(self) -> int:
        return len(self.throughput_lb)

    def __getitem__(self, i):
        return self.throughput_lb[i]

    def __iter__(self):
        return iter(self.throughput_lb)


def _masked(cap, dem, n_valid):
    nmax = cap.shape[0]
    node_mask = jnp.arange(nmax) < n_valid
    pair_mask = node_mask[:, None] & node_mask[None, :]
    cap = jnp.where(pair_mask, cap, 0.0)
    dem = jnp.where(pair_mask, dem, 0.0)
    edge_mask = (cap > 0) & pair_mask
    safe_cap = jnp.where(edge_mask, cap, 1.0)
    return cap, dem, edge_mask, safe_cap


def _ecmp_eval(dem, edge_mask, safe_cap, *, backend, interpret, d_max,
               max_rounds, hops):
    """Exact ECMP loads via the fixed point of the equal-split operator.

    ``split[v, u, t]`` sends an equal share of v's t-bound traffic to
    every neighbour u one hop closer to t (SP-DAG membership on unit-hop
    distances; exact small integers, so the 0.5 tolerance is exact).
    The operator strictly decreases distance-to-go, so ``hops`` >=
    diameter applications of ``inflow = dem + inflow @ split`` reach the
    fixed point; the loads it induces are an explicit feasible routing
    of the full demand and ``1 / umax`` is certified.
    """
    nmax = edge_mask.shape[0]
    eye = jnp.eye(nmax, dtype=bool)
    w = jnp.where(edge_mask, 1.0, _INF)
    w = jnp.where(eye, 0.0, w)
    dist = apsp(w, backend, interpret, d_max, max_rounds)
    reach = dist < _INF / 2
    routable = ~jnp.any((dem > 0) & ~reach)
    nh = edge_mask[:, :, None] & reach[:, None, :] & \
        (jnp.abs(dist[:, None, :] - 1.0 - dist[None, :, :]) < 0.5)
    cnt = nh.sum(axis=1)                                   # [v, t]
    split = jnp.where(nh, 1.0 / jnp.maximum(cnt, 1)[:, None, :], 0.0)

    def body(_, inflow):
        return dem + jnp.einsum("vt,vut->ut", inflow, split)

    inflow = jax.lax.fori_loop(0, hops, body, dem)
    loads = jnp.einsum("vt,vut->vu", inflow, split)
    util = jnp.max(jnp.where(edge_mask, loads / safe_cap, 0.0))
    lb = jnp.where(routable & (util > 0),
                   1.0 / jnp.maximum(util, 1e-30), 0.0)
    return lb, util


def _ideal_ub(cap, dem, n_valid, lr_peak, tol, *, iters, check_every,
              backend, interpret, d_max, max_rounds):
    """Ideal dual upper bound from the shared descent (free bracket)."""
    best, it, z, dem_m, loss_of = _descend(
        cap, dem, n_valid, lr_peak, tol, iters=iters,
        check_every=check_every, backend=backend, interpret=interpret,
        d_max=d_max, max_rounds=max_rounds)
    _, final_ratio = loss_of(z, dem_m)
    return jnp.minimum(best, final_ratio), it


def _ecmp_one(cap, dem, n_valid, lr_peak, tol, *, iters, check_every,
              backend, interpret, d_max=None, max_rounds=None, hops):
    """One (possibly padded) instance: (ecmp lb, ideal ub, util, iters)."""
    capm, demm, edge_mask, safe_cap = _masked(cap, dem, n_valid)
    lb, util = _ecmp_eval(demm, edge_mask, safe_cap, backend=backend,
                          interpret=interpret, d_max=d_max,
                          max_rounds=max_rounds, hops=hops)
    ub, it = _ideal_ub(cap, dem, n_valid, lr_peak, tol, iters=iters,
                       check_every=check_every, backend=backend,
                       interpret=interpret, d_max=d_max,
                       max_rounds=max_rounds)
    return lb, ub, util, it


def _ksp_one(cap, dem, n_valid, paths, lr_peak, tol, *, iters,
             check_every, backend, interpret, d_max=None, max_rounds=None,
             hops):
    """One (possibly padded) instance of the k-path multiplicative-weights
    program: (ksp lb floored by ecmp, ideal ub, final util, MW iters).

    ``paths``: int32 ``[nmax * nmax, k, max_hops + 1]`` from
    ``repro.kernels.paths`` (-1 padded).  Certification: every iterate's
    exact utilization bounds a true feasible routing, and the ECMP
    evaluation shares this program's masks, so ``lb >= ecmp`` holds by
    construction (the documented lattice direction).
    """
    nmax = cap.shape[0]
    capm, demm, edge_mask, safe_cap = _masked(cap, dem, n_valid)
    ecmp_lb, _ = _ecmp_eval(demm, edge_mask, safe_cap, backend=backend,
                            interpret=interpret, d_max=d_max,
                            max_rounds=max_rounds, hops=hops)
    ub, _ = _ideal_ub(cap, dem, n_valid, lr_peak, tol, iters=iters,
                      check_every=check_every, backend=backend,
                      interpret=interpret, d_max=d_max,
                      max_rounds=max_rounds)

    a = paths[:, :, :-1]
    b = paths[:, :, 1:]
    hop_ok = (a >= 0) & (b >= 0)
    eidx = jnp.clip(a, 0) * nmax + jnp.clip(b, 0)          # [P, K, H]
    valid = paths[:, :, 0] >= 0                            # [P, K]
    demv = demm.reshape(-1)                                # [P]
    covered = jnp.any(valid, axis=1)
    routable = ~jnp.any((demv > 0) & ~covered)
    emask_f = edge_mask.reshape(-1)
    scap_f = safe_cap.reshape(-1)

    def util_of(logits):
        x = jax.nn.softmax(jnp.where(valid, logits, -1e9), axis=1)
        wgt = jnp.where(valid, x, 0.0) * demv[:, None]     # [P, K]
        contrib = jnp.where(hop_ok, wgt[:, :, None], 0.0)
        loads = jnp.zeros(nmax * nmax, jnp.float32).at[eidx].add(contrib)
        u = jnp.where(emask_f, loads / scap_f, 0.0)
        umax = jnp.max(u)
        # smooth surrogate: temperature-scaled logsumexp whose scale
        # tracks the (stop-gradient) current max, so the gradient always
        # resolves ties among near-tight edges at the same resolution
        s = jax.lax.stop_gradient(jnp.maximum(umax, 1e-30))
        soft = s / _MW_BETA * jax.nn.logsumexp(
            jnp.where(emask_f, u, -jnp.inf) * (_MW_BETA / s))
        return soft, umax

    grad_fn = jax.value_and_grad(util_of, has_aux=True)

    def lb_of(umax):
        return jnp.where(umax > 0, 1.0 / jnp.maximum(umax, 1e-30), 0.0)

    def cond(state):
        i = state[0]
        done = state[-1]
        return (i < iters) & ~done

    def step(state):
        i, logits, m, v, best, ref_best, _ = state
        (_, umax), g = grad_fn(logits)
        best = jnp.maximum(best, lb_of(umax))
        # Adam with cosine-decayed lr (mirrors the dual descent)
        t = i + 1
        lr = lr_peak * 0.5 * (1 + jnp.cos(jnp.pi * i / iters)) + 1e-3
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        logits = logits - lr * mh / (jnp.sqrt(vh) + 1e-8)
        at_check = t % check_every == 0
        rel_gain = (best - ref_best) / jnp.maximum(best, 1e-30)
        done = at_check & (rel_gain < tol)
        ref_best = jnp.where(at_check, best, ref_best)
        return t, logits, m, v, best, ref_best, done

    z0 = jnp.zeros(valid.shape, jnp.float32)   # uniform split at step 0
    init = (jnp.int32(0), z0, jnp.zeros_like(z0), jnp.zeros_like(z0),
            jnp.float32(0.0), jnp.float32(0.0), jnp.bool_(False))
    it, logits, _, _, best, _, _ = jax.lax.while_loop(cond, step, init)
    _, final_umax = util_of(logits)
    best = jnp.maximum(best, lb_of(final_umax))
    mw_lb = jnp.where(routable, best, 0.0)
    lb = jnp.maximum(mw_lb, ecmp_lb)           # the ECMP floor
    return lb, ub, final_umax, it


# compile-key statics: the dual/primal set plus the ECMP propagation
# depth (``hops``), which is resolved from the padded width only so
# every chunk of a bucket — and every ``refill`` round — shares keys
_STATIC = ("iters", "check_every", "backend", "interpret", "d_max",
           "max_rounds", "hops")


@functools.partial(jax.jit, static_argnames=_STATIC)
def _ecmp(cap, dem, n_valid, lr_peak, tol, *, iters, check_every,
          backend, interpret, d_max=None, max_rounds=None, hops=None):
    return _ecmp_one(cap, dem, n_valid, lr_peak, tol, iters=iters,
                     check_every=check_every, backend=backend,
                     interpret=interpret, d_max=d_max,
                     max_rounds=max_rounds, hops=hops)


@functools.partial(jax.jit, static_argnames=_STATIC)
def _ksp(cap, dem, n_valid, paths, lr_peak, tol, *, iters, check_every,
         backend, interpret, d_max=None, max_rounds=None, hops=None):
    return _ksp_one(cap, dem, n_valid, paths, lr_peak, tol, iters=iters,
                    check_every=check_every, backend=backend,
                    interpret=interpret, d_max=d_max,
                    max_rounds=max_rounds, hops=hops)


def _ecmp_batch_impl(caps, dems, n_valid, lr_peak, tol, *, iters,
                     check_every, backend, interpret, d_max=None,
                     max_rounds=None, hops=None):
    fn = functools.partial(_ecmp_one, iters=iters, check_every=check_every,
                           backend=backend, interpret=interpret,
                           d_max=d_max, max_rounds=max_rounds, hops=hops)
    return jax.vmap(fn, in_axes=(0, 0, 0, None, None))(
        caps, dems, n_valid, lr_peak, tol)


def _ksp_batch_impl(caps, dems, n_valid, paths, lr_peak, tol, *, iters,
                    check_every, backend, interpret, d_max=None,
                    max_rounds=None, hops=None):
    fn = functools.partial(_ksp_one, iters=iters, check_every=check_every,
                           backend=backend, interpret=interpret,
                           d_max=d_max, max_rounds=max_rounds, hops=hops)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, None, None))(
        caps, dems, n_valid, paths, lr_peak, tol)


_ecmp_batch = jax.jit(_ecmp_batch_impl, static_argnames=_STATIC)
_ecmp_batch_donated = jax.jit(_ecmp_batch_impl, static_argnames=_STATIC,
                              donate_argnums=(0, 1))
_ksp_batch = jax.jit(_ksp_batch_impl, static_argnames=_STATIC)
_ksp_batch_donated = jax.jit(_ksp_batch_impl, static_argnames=_STATIC,
                             donate_argnums=(0, 1))


def compile_cache_sizes() -> dict[str, int | None]:
    """Compiled program variants per routing entry point (mirrors
    ``mcf.compile_cache_sizes``; ``None`` = introspection unavailable)."""
    return {"ecmp": jit_cache_size(_ecmp),
            "ecmp_batch": jit_cache_size(_ecmp_batch, _ecmp_batch_donated),
            "ksp": jit_cache_size(_ksp),
            "ksp_batch": jit_cache_size(_ksp_batch, _ksp_batch_donated)}


def _resolve_hops(nmax: int, hops: int | None) -> int:
    # depth of the ECMP fixed-point loop; nmax always covers the
    # diameter, and depending only on the padded width keeps compile
    # keys shared across a bucket's chunks and refill rounds
    return int(hops) if hops is not None else int(nmax)


def _resolve_max_hops(nmax: int, max_hops: int | None) -> int:
    return int(max_hops) if max_hops is not None \
        else min(int(nmax) - 1, DEFAULT_MAX_HOPS)


def _paths_tensor(caps: np.ndarray, n_valid: np.ndarray, k: int,
                  max_hops: int) -> np.ndarray:
    """Host-side per-lane path enumeration, deduped across identical
    lanes (plan padding replicates instance 0 into surplus lanes, so
    those are free).  Capacity beyond each lane's ``n_valid`` is zeroed
    first, so no path ever visits a padded node."""
    caps = np.asarray(caps)
    r, nmax = caps.shape[0], caps.shape[1]
    node_ok = np.arange(nmax)[None, :] < np.asarray(n_valid)[:, None]
    masked = np.where(node_ok[:, :, None] & node_ok[:, None, :], caps, 0.0)
    out = np.empty((r, nmax * nmax, k, max_hops + 1), np.int32)
    cache: dict[bytes, np.ndarray] = {}
    for i in range(r):
        key = masked[i].tobytes()
        hit = cache.get(key)
        if hit is None:
            hit = kpaths.k_shortest_paths(masked[i], k, max_hops)
            hit = hit.reshape(nmax * nmax, k, max_hops + 1)
            cache[key] = hit
        out[i] = hit
    return out


def solve_ecmp(cap: Topology | np.ndarray, dem: np.ndarray, *,
               iters: int = 800, lr: float = 0.08, tol: float = 0.0,
               check_every: int = 25, use_pallas: bool = False,
               interpret: bool | None = None, backend: str | None = None,
               aot=None, d_max: int | None = None,
               max_rounds: int | None = None,
               hops: int | None = None) -> RoutingResult:
    """Certified ECMP lower bound for one instance (module docstring);
    the ideal dual upper bound rides along from the fused descent.
    ``hops`` caps the fixed-point propagation depth (default: N, always
    enough); the descent knobs only steer the free upper bound."""
    del aot
    interpret = kops.resolve_interpret(interpret)
    cap_host = as_cap(cap)
    n = cap_host.shape[0]
    backend, d_max = resolve_backend_density(
        normalize_backend(backend, use_pallas), cap_host, n=n, d_max=d_max)
    lb, ub, util, it = _ecmp(
        jnp.asarray(cap_host, jnp.float32), jnp.asarray(dem, jnp.float32),
        jnp.int32(n), jnp.float32(lr), jnp.float32(tol), iters=iters,
        check_every=check_every, backend=backend, interpret=interpret,
        d_max=d_max, max_rounds=max_rounds, hops=_resolve_hops(n, hops))
    return RoutingResult(float(lb), float(ub), float(util), int(it))


def solve_ksp(cap: Topology | np.ndarray, dem: np.ndarray, *,
              k: int = DEFAULT_K, max_hops: int | None = None,
              iters: int = 800, lr: float = 0.08, tol: float = 0.0,
              check_every: int = 25, use_pallas: bool = False,
              interpret: bool | None = None, backend: str | None = None,
              aot=None, d_max: int | None = None,
              max_rounds: int | None = None,
              hops: int | None = None) -> RoutingResult:
    """Certified k-shortest-path lower bound for one instance (module
    docstring): multiplicative weights over the k-path set, floored by
    the ECMP baseline, with the ideal dual upper bound riding along."""
    del aot
    interpret = kops.resolve_interpret(interpret)
    cap_host = as_cap(cap)
    n = cap_host.shape[0]
    backend, d_max = resolve_backend_density(
        normalize_backend(backend, use_pallas), cap_host, n=n, d_max=d_max)
    mh = _resolve_max_hops(n, max_hops)
    paths = _paths_tensor(cap_host[None], np.full(1, n, np.int32), k, mh)[0]
    lb, ub, util, it = _ksp(
        jnp.asarray(cap_host, jnp.float32), jnp.asarray(dem, jnp.float32),
        jnp.int32(n), jnp.asarray(paths), jnp.float32(lr),
        jnp.float32(tol), iters=iters, check_every=check_every,
        backend=backend, interpret=interpret, d_max=d_max,
        max_rounds=max_rounds, hops=_resolve_hops(n, hops))
    return RoutingResult(float(lb), float(ub), float(util), int(it))


def _prep_batch(caps, dems, n_valid, backend, use_pallas, d_max,
                mean_degree):
    if len(caps) != len(dems):
        raise ValueError(f"caps ({len(caps)}) and dems ({len(dems)}) "
                         "must have equal length")
    if not isinstance(caps, (np.ndarray, jax.Array)):
        caps = np.stack([as_cap(c) for c in caps])
    if not isinstance(dems, (np.ndarray, jax.Array)):
        dems = np.stack([np.asarray(d) for d in dems])
    if n_valid is None:
        n_valid = np.full(caps.shape[0], caps.shape[1], np.int32)
    backend, d_max = resolve_backend_density(
        normalize_backend(backend, use_pallas), caps, n=caps.shape[1],
        d_max=d_max, mean_degree=mean_degree)
    return caps, dems, np.asarray(n_valid, np.int32), backend, d_max


def _empty_batch() -> RoutingBatchResult:
    z = np.zeros(0, np.float32)
    return RoutingBatchResult(z, z.copy(), z.copy(), np.zeros(0, np.int32))


def solve_ecmp_batch(caps, dems, *, n_valid=None, iters: int = 800,
                     lr: float = 0.08, tol: float = 0.0,
                     check_every: int = 25, use_pallas: bool = False,
                     interpret: bool | None = None,
                     backend: str | None = None, aot=None, sharding=None,
                     donate: bool = False, block: bool = True,
                     d_max: int | None = None,
                     mean_degree: float | None = None,
                     max_rounds: int | None = None,
                     hops: int | None = None) -> RoutingBatchResult:
    """Batched ECMP solve over stacked [R, N, N] topologies/demands; the
    call surface mirrors ``mcf.solve_dual_batch`` exactly (``n_valid``
    padding masks, ``sharding``/``donate``/``block`` for the
    ``BatchPlan`` async path, ``aot`` persistent compile cache)."""
    interpret = kops.resolve_interpret(interpret)
    if len(caps) == 0:
        return _empty_batch()
    caps, dems, n_valid, backend, d_max = _prep_batch(
        caps, dems, n_valid, backend, use_pallas, d_max, mean_degree)
    capj = jnp.asarray(caps, jnp.float32)
    demj = jnp.asarray(dems, jnp.float32)
    nvj = jnp.asarray(n_valid, jnp.int32)
    if sharding is not None:
        capj, demj, nvj = jax.device_put((capj, demj, nvj), sharding)
    fn = _ecmp_batch_donated if donate else _ecmp_batch
    args = (capj, demj, nvj, jnp.float32(lr), jnp.float32(tol))
    static_kw = dict(iters=iters, check_every=check_every, backend=backend,
                     interpret=interpret, d_max=d_max,
                     max_rounds=max_rounds,
                     hops=_resolve_hops(caps.shape[1], hops))
    with warnings.catch_warnings():
        # outputs are per-lane scalars, so XLA reports the donation unused
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        if aot is not None and sharding is None:
            lb, ub, util, it = aot.call(
                fn, ("ecmp", "donated" if donate else "plain"),
                args, static_kw)
        else:
            lb, ub, util, it = fn(*args, **static_kw)
    if not block:
        return RoutingBatchResult(lb, ub, util, it)
    return RoutingBatchResult(np.asarray(lb), np.asarray(ub),
                              np.asarray(util), np.asarray(it))


def solve_ksp_batch(caps, dems, *, n_valid=None, k: int = DEFAULT_K,
                    max_hops: int | None = None, iters: int = 800,
                    lr: float = 0.08, tol: float = 0.0,
                    check_every: int = 25, use_pallas: bool = False,
                    interpret: bool | None = None,
                    backend: str | None = None, aot=None, sharding=None,
                    donate: bool = False, block: bool = True,
                    d_max: int | None = None,
                    mean_degree: float | None = None,
                    max_rounds: int | None = None,
                    hops: int | None = None) -> RoutingBatchResult:
    """Batched KSP solve; surface = ``solve_ecmp_batch`` plus the path
    knobs ``k`` (paths per pair) and ``max_hops`` (per-path hop budget,
    default min(N - 1, DEFAULT_MAX_HOPS) — resolved from the padded
    width only, so refill rounds share compile keys).  Path tensors are
    enumerated host-side per lane (deduped across identical lanes)."""
    interpret = kops.resolve_interpret(interpret)
    if len(caps) == 0:
        return _empty_batch()
    caps, dems, n_valid, backend, d_max = _prep_batch(
        caps, dems, n_valid, backend, use_pallas, d_max, mean_degree)
    mh = _resolve_max_hops(caps.shape[1], max_hops)
    paths = _paths_tensor(np.asarray(caps), n_valid, k, mh)
    capj = jnp.asarray(caps, jnp.float32)
    demj = jnp.asarray(dems, jnp.float32)
    nvj = jnp.asarray(n_valid, jnp.int32)
    pj = jnp.asarray(paths)
    if sharding is not None:
        capj, demj, nvj, pj = jax.device_put((capj, demj, nvj, pj),
                                             sharding)
    fn = _ksp_batch_donated if donate else _ksp_batch
    args = (capj, demj, nvj, pj, jnp.float32(lr), jnp.float32(tol))
    static_kw = dict(iters=iters, check_every=check_every, backend=backend,
                     interpret=interpret, d_max=d_max,
                     max_rounds=max_rounds,
                     hops=_resolve_hops(caps.shape[1], hops))
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        if aot is not None and sharding is None:
            lb, ub, util, it = aot.call(
                fn, ("ksp", "donated" if donate else "plain"),
                args, static_kw)
        else:
            lb, ub, util, it = fn(*args, **static_kw)
    if not block:
        return RoutingBatchResult(lb, ub, util, it)
    return RoutingBatchResult(np.asarray(lb), np.asarray(ub),
                              np.asarray(util), np.asarray(it))


def path_lp_throughput(cap: Topology | np.ndarray, dem: np.ndarray,
                       paths: np.ndarray) -> float:
    """Exact path-restricted max concurrent flow via scipy ``linprog``
    (HiGHS) — the small-instance cross-check for the MW solver.

    Variables are θ plus one flow per (demanded pair, valid path);
    conservation ties each pair's path flows to θ·dem, and every
    directed edge's summed load is capped.  ``paths`` is a
    ``[N, N, k, H + 1]`` or ``[N², k, H + 1]`` tensor from
    ``repro.kernels.paths``.  Returns 0.0 when any demanded pair has no
    path in the set (the restriction makes the demand unroutable).
    """
    from scipy.optimize import linprog

    cap = as_cap(cap)
    n = cap.shape[0]
    p = np.asarray(paths).reshape(n * n, *np.asarray(paths).shape[-2:])
    demv = np.asarray(dem, np.float64).reshape(-1)
    valid = p[:, :, 0] >= 0
    pairs = np.nonzero(demv > 0)[0]
    if len(pairs) == 0:
        return 0.0
    if not valid[pairs].any(axis=1).all():
        return 0.0
    ei, ej = np.nonzero(cap > 0)
    e_of = {(int(a), int(b)): r for r, (a, b) in enumerate(zip(ei, ej))}
    cols = [(pi, ki) for pi in pairs for ki in np.nonzero(valid[pi])[0]]
    nv = 1 + len(cols)
    a_ub = np.zeros((len(ei), nv))
    for c, (pi, ki) in enumerate(cols):
        seq = p[pi, ki]
        seq = seq[seq >= 0]
        for x, y in zip(seq[:-1], seq[1:]):
            a_ub[e_of[(int(x), int(y))], 1 + c] += 1.0
    a_eq = np.zeros((len(pairs), nv))
    for r, pi in enumerate(pairs):
        a_eq[r, 0] = -demv[pi]
        for c, (pj_, _) in enumerate(cols):
            if pj_ == pi:
                a_eq[r, 1 + c] = 1.0
    c_vec = np.zeros(nv)
    c_vec[0] = -1.0
    res = linprog(c_vec, A_ub=a_ub, b_ub=cap[ei, ej],
                  A_eq=a_eq, b_eq=np.zeros(len(pairs)),
                  bounds=[(0, None)] * nv, method="highs")
    if not res.success:
        raise RuntimeError(f"path LP failed: {res.message}")
    return float(res.x[0])
