"""JAX maximum-concurrent-flow solver via dual (LP-duality) descent.

LP duality for max concurrent flow: with edge lengths l >= 0,

    theta* = min_l  sum_e c_e l_e  /  sum_{(s,t)} dem(s,t) * dist_l(s, t)

Every iterate gives a *certified upper bound* on theta* (scale l so the
demand-weighted distance is 1); at the optimum the bound is tight.  We
minimise the log-ratio with Adam in log-length space.  dist_l is all-pairs
shortest paths via ``repro.core.apsp`` — an ``ApspBackend`` registry
(``"squaring" | "squaring-pallas" | "blocked-fw" | "auto"``) whose shared
custom VJP yields shortest-path-DAG subgradients identically on every
backend.  ``backend`` selects it; the legacy ``use_pallas`` flag keeps
working and maps onto the registry (True -> "squaring-pallas").

This is the paper's CPLEX replacement that actually scales: it is pure
dense linear algebra, jit/vmap-able over topology batches (the paper's "20
runs per point" becomes one batched solve), and sharding the N x N distance
matrices over a mesh distributes the solve.

Batching over *mixed* topology sizes works by padding every instance up to a
common bucket size and passing per-instance valid node counts (``n_valid``):
padded nodes carry zero capacity, zero demand, and ``_INF`` edge weights, so
they contribute nothing to the dual ratio or its gradient.  The descent loop
is a ``lax.while_loop`` with convergence-based early stopping (relative
improvement of the best bound per ``check_every``-iteration window), so a
batch lane that converges stops updating while slower lanes continue.

``interpret`` controls the Pallas kernel execution mode; ``None`` (the
default) auto-detects from ``jax.default_backend()`` — compiled on TPU,
interpreter elsewhere.

This solver certifies only one side of theta*: every iterate UPPER-bounds
the optimum.  Its primal companion, ``repro.core.primal``, reuses ``apsp``
and the same masking/padding conventions to certify the LOWER side from an
explicit feasible flow, and ``repro.core.plan.BatchPlan`` drives both
through identical buckets/chunks/device shards (``solver="dual"`` /
``"primal"``).

Validation: tests/test_flow.py checks the dual bound converges to the HiGHS
exact optimum within a few percent on paper-scale instances, and
tests/test_conformance.py pins ``primal.lb <= theta_exact <= dual.ub``
across traffic patterns x topology families.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apsp as apsp_mod
from repro.core.apsp import _INF, normalize_backend
from repro.core.graphs import (Topology, as_cap, connected_components,
                               degree_stats)
from repro.kernels import ops as kops

__all__ = ["DualResult", "DualBatchResult", "DualDemgradBatchResult",
           "apsp", "solve_dual", "solve_dual_batch",
           "solve_dual_demgrad_batch", "aspl", "drop_disconnected",
           "jit_cache_size", "compile_cache_sizes",
           "resolve_backend_density", "_INF"]


@dataclasses.dataclass(frozen=True)
class DualResult:
    """One instance's dual solve: a certified UPPER bound on θ* (the
    max concurrent flow rate per unit demand, dimensionless given
    ``cap``/``dem`` in consistent base line-speed units).  θ* ≤
    ``throughput_ub`` always; equality in the limit."""

    throughput_ub: float      # best certified dual bound on theta*
    final_ratio: float        # ratio at the last iterate (convergence probe)
    iterations: int           # descent steps actually executed (<= cap)


@dataclasses.dataclass(frozen=True)
class DualBatchResult:
    """Per-instance solver outputs of one batched solve.

    Indexing/iteration yield the certified bounds (``throughput_ub``) so the
    object drops into code that treated the old ``np.ndarray`` return value
    as a sequence of bounds.  A ``block=False`` solve carries in-flight
    ``jax.Array``s instead of host arrays (sync with
    ``jax.block_until_ready``).
    """

    throughput_ub: np.ndarray   # [B] best certified dual bound per instance
    final_ratio: np.ndarray     # [B] ratio at each instance's last iterate
    iterations: np.ndarray      # [B] descent steps executed per instance

    def __len__(self) -> int:
        return len(self.throughput_ub)

    def __getitem__(self, i):
        return self.throughput_ub[i]

    def __iter__(self):
        return iter(self.throughput_ub)


@dataclasses.dataclass(frozen=True)
class DualDemgradBatchResult:
    """A batched dual solve that ALSO differentiates the bound w.r.t. the
    demand matrix (the adversarial-traffic search's workhorse).

    ``dem_grad[b]`` is the gradient of the converged log-ratio loss
    ``log D(l*) − log α(l*)`` w.r.t. ``dems[b]``, evaluated at the final
    edge lengths l* — a Danskin supergradient of ``log θ*(dem)``: at the
    dual optimum the bound's dem-sensitivity is ``−dist(s, t)/α`` on
    valid pairs (distances do not depend on demand, so this costs one
    extra APSP forward and NO APSP backward).  Descending ``dem`` along
    it (inside the hose polytope) lowers the achievable throughput.
    """

    throughput_ub: np.ndarray   # [B] best certified dual bound per instance
    final_ratio: np.ndarray     # [B] ratio at each instance's last iterate
    iterations: np.ndarray      # [B] descent steps executed per instance
    dem_grad: np.ndarray        # [B, N, N] d loss / d dem at the final l*

    def __len__(self) -> int:
        return len(self.throughput_ub)


def apsp(w: jax.Array, backend: str | bool | None = "auto",
         interpret: bool | None = None, d_max: int | None = None,
         max_rounds: int | None = None) -> jax.Array:
    """All-pairs shortest paths of a weighted adjacency matrix.  ``w``:
    [N, N] edge lengths (any consistent unit; hops when 1 per edge),
    ``_INF`` for non-edges, 0 diagonal.  Returns [N, N] distances in the
    same unit; unreachable pairs stay ~``_INF`` (compare against
    ``_INF / 2``, never equality).

    ``backend`` names an ``ApspBackend`` (see ``repro.core.apsp``);
    legacy boolean ``use_pallas`` values are accepted in the same slot
    (True -> "squaring-pallas").  ``d_max``/``max_rounds`` are the
    ``"ell-bf"`` statics (table width / relaxation-round cap).
    Differentiable on every backend — the shared VJP is the
    shortest-path-DAG subgradient both solvers consume."""
    return apsp_mod.apsp(w, normalize_backend(backend), interpret,
                         d_max, max_rounds)


def resolve_backend_density(backend: str, caps, *, n: int,
                            d_max: int | None = None,
                            mean_degree: float | None = None,
                            ) -> tuple[str, int | None]:
    """Host-side density resolution shared by the dual/primal solvers:
    decide whether ``backend`` lands on ``"ell-bf"`` and with what table
    width.  Returns ``(backend, d_max)`` where ``d_max`` is None unless
    the resolved backend is ``"ell-bf"``.

    Dense resolutions pass ``backend`` through UNCHANGED (``"auto"``
    stays ``"auto"``), so dense solves keep their existing jit/AOT cache
    keys.  ``caps`` (an instance or stacked batch of capacity matrices)
    is only scanned when the caller did not already supply the stats —
    ``BatchPlan`` passes per-chunk hints computed before padding."""
    if backend not in ("auto", "ell-bf"):
        return backend, None
    if d_max is None or (backend == "auto" and mean_degree is None):
        stats_d_max, stats_mean = degree_stats(np.asarray(caps))
        if d_max is None:
            d_max = stats_d_max
        if mean_degree is None:
            mean_degree = stats_mean
    resolved = apsp_mod.resolve_backend(backend, n, mean_degree=mean_degree)
    if resolved != "ell-bf":
        return backend, None
    return "ell-bf", max(1, int(d_max))


def aspl(cap: Topology | np.ndarray | jax.Array,
         dem: np.ndarray | jax.Array | None = None,
         use_pallas: bool = False,
         interpret: bool | None = None,
         on_disconnected: str = "raise", *,
         backend: str | None = None) -> float:
    """Average shortest-path length in hops (demand-weighted if dem given).

    ``cap``: ``Topology`` or [N, N] capacities (only the nonzero pattern
    matters — every present link counts as one hop); ``dem``: optional
    [N, N] weights.  Disconnected pairs are excluded from the average.

    ``on_disconnected`` pins what a demanded-but-disconnected pair means
    (the failure-injection path hits these constantly):

    * ``"raise"`` (default) — ``ValueError``: such a pair's "distance"
      would be the ``_INF`` sentinel, not a meaningful path length.
    * ``"drop"`` — zero that pair's demand and average over what remains
      (graceful degradation: the dropped share of demand is what
      ``drop_disconnected`` reports).  If every demanded pair is
      disconnected the average is over nothing and 0.0 is returned.
    """
    if on_disconnected not in ("raise", "drop"):
        raise ValueError(f"on_disconnected must be 'raise' or 'drop', got "
                         f"{on_disconnected!r}")
    cap_host = np.asarray(as_cap(cap))
    n = cap_host.shape[0]
    # hop-metric probes over big degree-bounded graphs are exactly where
    # the sparse backend pays off — resolve density host-side
    bk, d_max = resolve_backend_density(
        normalize_backend(backend, use_pallas), cap_host, n=n)
    cap = jnp.asarray(cap_host, jnp.float32)
    w = jnp.where(cap > 0, 1.0, _INF)
    w = jnp.where(jnp.eye(n, dtype=bool), 0.0, w)
    d = apsp(w, bk, interpret, d_max)
    reachable = d < _INF / 2
    if dem is None:
        mask = (~jnp.eye(n, dtype=bool)) & reachable
        return float(jnp.where(mask, d, 0.0).sum() / mask.sum())
    dem = jnp.asarray(dem, jnp.float32)
    if bool(((dem > 0) & ~reachable).any()):
        if on_disconnected == "raise":
            bad = int(((dem > 0) & ~np.asarray(reachable)).sum())
            raise ValueError(
                f"{bad} demanded (s, t) pair(s) are disconnected; "
                "demand-weighted ASPL is undefined on this topology "
                "(pass on_disconnected='drop' to average over the "
                "routable demand only)")
        dem = jnp.where(reachable, dem, 0.0)
        if float(dem.sum()) == 0.0:
            return 0.0
    d = jnp.where(reachable, d, 0.0)
    return float((d * dem).sum() / dem.sum())


def drop_disconnected(cap: Topology | np.ndarray,
                      dem: np.ndarray) -> tuple[np.ndarray, float]:
    """Zero the demand of every (s, t) pair with no path in ``cap``.

    Returns ``(kept_dem, dropped_fraction)`` where ``dropped_fraction`` is
    the share of the total demand that was zeroed (0.0 on a connected
    topology, 1.0 when nothing is routable).  This is the graceful-
    degradation contract of the lifecycle subsystem: failure scenarios
    never crash a solver or leak an ``_INF`` — unroutable demand is
    dropped here and reported as ``reachable_fraction = 1 - dropped``.
    Reachability is a host-side connected-components pass (cheap), not an
    APSP."""
    labels = connected_components(cap)
    dem = np.asarray(dem, np.float64)
    total = float(dem.sum())
    if total == 0.0:
        return dem.copy(), 0.0
    keep = labels[:, None] == labels[None, :]
    kept = np.where(keep, dem, 0.0)
    return kept, float((total - kept.sum()) / total)


def _dual_ratio(z: jax.Array, cap: jax.Array, dem: jax.Array,
                edge_mask: jax.Array, pair_mask: jax.Array, eye: jax.Array,
                backend: str, interpret: bool,
                d_max: int | None = None, max_rounds: int | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Returns (log-ratio loss, certified bound D(l)/alpha(l)).

    ``pair_mask`` marks (valid, valid) node pairs of a padded instance;
    padded nodes are excluded from both sums: their edges carry ``_INF``
    weight (``edge_mask`` is False there, so also zero ``d_val`` weight) and
    their distances are zeroed before the demand-weighted ``alpha`` sum.
    """
    l = jnp.exp(z)
    w = jnp.where(edge_mask, l, _INF)
    w = jnp.where(eye, 0.0, w)
    dist = apsp(w, backend, interpret, d_max, max_rounds)
    alpha = (dem * jnp.where(pair_mask, dist, 0.0)).sum()
    d_val = (cap * l * edge_mask).sum()
    ratio = d_val / alpha
    return jnp.log(d_val) - jnp.log(alpha), ratio


def _descend(cap: jax.Array, dem: jax.Array, n_valid: jax.Array,
             lr_peak: jax.Array, tol: jax.Array, *, iters: int,
             check_every: int, backend: str, interpret: bool,
             d_max: int | None = None, max_rounds: int | None = None):
    """Masked Adam descent over one (possibly padded) instance: nodes >=
    n_valid are masked out.

    Early stopping: every ``check_every`` steps, stop when the best bound's
    relative improvement over the window falls below ``tol`` (monotone best
    => improvement >= 0, so ``tol=0`` never stops early).  All state updates
    are chosen via the ``lax.while_loop`` carry, so under ``vmap`` converged
    batch lanes hold their state while the remaining lanes keep descending.

    Returns ``(best, it, z, dem_m, loss_of)`` — the running-best bound,
    iteration count, final edge-length logits z, the MASKED demand, and
    the masked ``loss_of(z, dem) -> (loss, ratio)`` closure, so callers
    can evaluate the final ratio and/or differentiate it w.r.t. ``dem``
    at the converged z (what the adversarial-traffic entry does).
    """
    nmax = cap.shape[0]
    node_mask = jnp.arange(nmax) < n_valid
    pair_mask = node_mask[:, None] & node_mask[None, :]
    cap = jnp.where(pair_mask, cap, 0.0)
    dem_m = jnp.where(pair_mask, dem, 0.0)
    edge_mask = (cap > 0) & pair_mask
    eye = jnp.eye(nmax, dtype=bool)
    z0 = jnp.zeros((nmax, nmax), jnp.float32)

    def loss_of(z, dem):
        return _dual_ratio(z, cap, dem, edge_mask, pair_mask, eye,
                           backend, interpret, d_max, max_rounds)

    grad_fn = jax.value_and_grad(lambda z: loss_of(z, dem_m), has_aux=True)

    def cond(state):
        i, _, _, _, _, _, done = state
        return (i < iters) & ~done

    def step(state):
        i, z, m, v, best, ref_best, _ = state
        (_, ratio), g = grad_fn(z)
        best = jnp.minimum(best, ratio)
        # Adam with cosine-decayed lr
        t = i + 1
        lr = lr_peak * 0.5 * (1 + jnp.cos(jnp.pi * i / iters)) + 1e-3
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        z = z - lr * mh / (jnp.sqrt(vh) + 1e-8)
        at_check = t % check_every == 0
        rel_gain = (ref_best - best) / jnp.maximum(best, 1e-30)
        done = at_check & (rel_gain < tol)
        ref_best = jnp.where(at_check, best, ref_best)
        return t, z, m, v, best, ref_best, done

    init = (jnp.int32(0), z0, jnp.zeros_like(z0), jnp.zeros_like(z0),
            jnp.float32(jnp.inf), jnp.float32(jnp.inf), jnp.bool_(False))
    it, z, _, _, best, _, _ = jax.lax.while_loop(cond, step, init)
    return best, it, z, dem_m, loss_of


def _solve_one(cap: jax.Array, dem: jax.Array, n_valid: jax.Array,
               lr_peak: jax.Array, tol: jax.Array, *, iters: int,
               check_every: int, backend: str, interpret: bool,
               d_max: int | None = None, max_rounds: int | None = None
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One (possibly padded) instance (see ``_descend``).

    Returns (best bound, final ratio, iterations executed).
    """
    best, it, z, dem_m, loss_of = _descend(
        cap, dem, n_valid, lr_peak, tol, iters=iters,
        check_every=check_every, backend=backend, interpret=interpret,
        d_max=d_max, max_rounds=max_rounds)
    _, final_ratio = loss_of(z, dem_m)
    best = jnp.minimum(best, final_ratio)
    return best, final_ratio, it


def _solve_one_demgrad(cap: jax.Array, dem: jax.Array, n_valid: jax.Array,
                       lr_peak: jax.Array, tol: jax.Array, *, iters: int,
                       check_every: int, backend: str, interpret: bool,
                       d_max: int | None = None, max_rounds: int | None = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """``_solve_one`` + the Danskin demand-gradient of the converged bound.

    At the final edge lengths l*, the log-ratio loss's gradient w.r.t.
    ``dem`` is ``−dist_l*(s, t) · pair_mask / α`` — the distances do not
    depend on demand, so ``jax.value_and_grad`` here triggers one extra
    APSP FORWARD (shared with the final-ratio evaluation) and no APSP
    backward.  Padded pairs get exactly zero gradient (``pair_mask``).

    Returns (best bound, final ratio, iterations, dem_grad[N, N]).
    """
    best, it, z, dem_m, loss_of = _descend(
        cap, dem, n_valid, lr_peak, tol, iters=iters,
        check_every=check_every, backend=backend, interpret=interpret,
        d_max=d_max, max_rounds=max_rounds)
    (_, final_ratio), g = jax.value_and_grad(
        lambda d: loss_of(z, d), has_aux=True)(dem_m)
    best = jnp.minimum(best, final_ratio)
    return best, final_ratio, it, g


# the solver statics — all compile-key material, including the ell-bf
# table width (d_max) and relaxation-round cap (max_rounds), which the
# AOT cache keys on via the static_kw repr
_STATIC = ("iters", "check_every", "backend", "interpret", "d_max",
           "max_rounds")


@functools.partial(jax.jit, static_argnames=_STATIC)
def _solve(cap, dem, n_valid, lr_peak, tol, *, iters, check_every,
           backend, interpret, d_max=None, max_rounds=None):
    return _solve_one(cap, dem, n_valid, lr_peak, tol, iters=iters,
                      check_every=check_every, backend=backend,
                      interpret=interpret, d_max=d_max,
                      max_rounds=max_rounds)


def _solve_batch_impl(caps, dems, n_valid, lr_peak, tol, *, iters,
                      check_every, backend, interpret, d_max=None,
                      max_rounds=None):
    fn = functools.partial(_solve_one, iters=iters, check_every=check_every,
                           backend=backend, interpret=interpret,
                           d_max=d_max, max_rounds=max_rounds)
    return jax.vmap(fn, in_axes=(0, 0, 0, None, None))(
        caps, dems, n_valid, lr_peak, tol)
_solve_batch = jax.jit(_solve_batch_impl, static_argnames=_STATIC)
# the planner owns its device buffers, so it donates caps/dems back to XLA;
# kept as a separate entry point so user-passed arrays are never invalidated
_solve_batch_donated = jax.jit(_solve_batch_impl, static_argnames=_STATIC,
                               donate_argnums=(0, 1))


def _solve_demgrad_batch_impl(caps, dems, n_valid, lr_peak, tol, *, iters,
                              check_every, backend, interpret, d_max=None,
                              max_rounds=None):
    fn = functools.partial(_solve_one_demgrad, iters=iters,
                           check_every=check_every, backend=backend,
                           interpret=interpret, d_max=d_max,
                           max_rounds=max_rounds)
    return jax.vmap(fn, in_axes=(0, 0, 0, None, None))(
        caps, dems, n_valid, lr_peak, tol)
_solve_demgrad_batch = jax.jit(_solve_demgrad_batch_impl,
                               static_argnames=_STATIC)
_solve_demgrad_batch_donated = jax.jit(_solve_demgrad_batch_impl,
                                       static_argnames=_STATIC,
                                       donate_argnums=(0, 1))


def jit_cache_size(*fns) -> int | None:
    """Total compiled-program count of the given jitted callables (one per
    distinct (shape, static-arg) combination), or ``None`` (not 0 — callers
    must not mistake "unavailable" for "no compiles") if the installed jax
    does not expose ``_cache_size``, which is a private API.  Shared by
    every solver backend's ``compile_cache_sizes``."""
    sizes = [getattr(fn, "_cache_size", None) for fn in fns]
    if not all(callable(s) for s in sizes):
        return None
    return sum(s() for s in sizes)


def compile_cache_sizes() -> dict[str, int | None]:
    """Compiled program variants per solver entry point.  Benchmarks report
    deltas of this to show "one compile per bucket"."""
    return {"solve": jit_cache_size(_solve),
            "solve_batch": jit_cache_size(_solve_batch,
                                          _solve_batch_donated),
            "solve_demgrad_batch": jit_cache_size(
                _solve_demgrad_batch, _solve_demgrad_batch_donated)}


def solve_dual(cap: Topology | np.ndarray, dem: np.ndarray, *,
               iters: int = 800, lr: float = 0.08, tol: float = 0.0,
               check_every: int = 25, use_pallas: bool = False,
               interpret: bool | None = None,
               backend: str | None = None, aot=None,
               d_max: int | None = None,
               max_rounds: int | None = None) -> DualResult:
    """Certified upper bound on max-concurrent-flow throughput (converges
    to the exact value; see module docstring).  ``cap``: a ``Topology``
    or symmetric [N, N] capacity matrix; ``dem``: [N, N] demand — both in
    units of the base line-speed, so the returned θ bound is the paper's
    dimensionless per-unit-demand rate.  ``iters`` caps the descent;
    ``tol > 0`` stops early once the bound's relative improvement per
    ``check_every``-step window drops below it.  ``backend`` picks the
    APSP backend (``repro.core.apsp.BACKENDS``; default auto, with
    ``use_pallas=True`` kept as an alias for "squaring-pallas").  ``aot``
    is accepted for signature parity with the batch entry point; the
    persistent compile cache only serves batched plans."""
    del aot   # single solves always JIT (plan lanes are the hot path)
    interpret = kops.resolve_interpret(interpret)
    cap_host = as_cap(cap)
    backend, d_max = resolve_backend_density(
        normalize_backend(backend, use_pallas), cap_host,
        n=cap_host.shape[0], d_max=d_max)
    capj = jnp.asarray(cap_host, jnp.float32)
    best, final, it = _solve(
        capj, jnp.asarray(dem, jnp.float32), jnp.int32(capj.shape[0]),
        jnp.float32(lr), jnp.float32(tol), iters=iters,
        check_every=check_every, backend=backend, interpret=interpret,
        d_max=d_max, max_rounds=max_rounds)
    return DualResult(float(best), float(final), int(it))


def solve_dual_batch(caps, dems, *, n_valid=None, iters: int = 800,
                     lr: float = 0.08, tol: float = 0.0,
                     check_every: int = 25, use_pallas: bool = False,
                     interpret: bool | None = None,
                     backend: str | None = None, aot=None,
                     sharding=None, donate: bool = False,
                     block: bool = True, d_max: int | None = None,
                     mean_degree: float | None = None,
                     max_rounds: int | None = None) -> DualBatchResult:
    """Batched solve over stacked [R, N, N] topologies/demands (the paper's
    '20 runs per data point' in a single vmapped program).  ``caps`` may be a
    stacked array or a sequence of Topologies/matrices of equal size; an
    empty sequence returns an empty ``DualBatchResult``.

    ``n_valid`` ([R] ints) marks how many leading nodes of each instance are
    real; the rest are padding (zero capacity/demand) and are masked out of
    the dual ratio.  Size-heterogeneous batches are padded into buckets and
    chunks by ``repro.core.plan.BatchPlan`` (which ``DualEngine.solve_batch``
    delegates to) — one compiled program per (bucket, chunk-shape).

    ``sharding`` (a ``jax.sharding.Sharding``, normally ``NamedSharding(mesh,
    P("batch"))`` over a 1-D mesh) commits the batch axis across devices; the
    batch dimension must then be a device-count multiple.  ``donate=True``
    hands the device input buffers back to XLA (only safe when the caller
    does not reuse ``caps``/``dems`` afterwards).  ``block=False`` skips the
    host transfer and returns in-flight device arrays — callers sync with
    ``jax.block_until_ready`` (what ``BatchPlan.execute`` does once over all
    of its chunks).

    ``backend`` selects the APSP backend (see ``repro.core.apsp``); ``aot``
    takes a ``repro.core.aotcache.AotCache`` to serve this chunk shape from
    the persistent ahead-of-time compile cache (single-device plans only;
    any cache failure falls back to plain JIT).
    """
    interpret = kops.resolve_interpret(interpret)
    backend = normalize_backend(backend, use_pallas)
    if len(caps) != len(dems):
        raise ValueError(f"caps ({len(caps)}) and dems ({len(dems)}) "
                         "must have equal length")
    if len(caps) == 0:
        return DualBatchResult(np.zeros(0, np.float32),
                               np.zeros(0, np.float32), np.zeros(0, np.int32))
    if not isinstance(caps, (np.ndarray, jax.Array)):
        caps = np.stack([as_cap(c) for c in caps])
    if not isinstance(dems, (np.ndarray, jax.Array)):
        dems = np.stack([np.asarray(d) for d in dems])
    if n_valid is None:
        n_valid = np.full(caps.shape[0], caps.shape[1], np.int32)
    backend, d_max = resolve_backend_density(
        backend, caps, n=caps.shape[1], d_max=d_max,
        mean_degree=mean_degree)
    capj = jnp.asarray(caps, jnp.float32)
    demj = jnp.asarray(dems, jnp.float32)
    nvj = jnp.asarray(n_valid, jnp.int32)
    if sharding is not None:
        capj, demj, nvj = jax.device_put((capj, demj, nvj), sharding)
    fn = _solve_batch_donated if donate else _solve_batch
    args = (capj, demj, nvj, jnp.float32(lr), jnp.float32(tol))
    static_kw = dict(iters=iters, check_every=check_every,
                     backend=backend, interpret=interpret,
                     d_max=d_max, max_rounds=max_rounds)
    with warnings.catch_warnings():
        # donated buffers alias outputs only when shapes permit; here the
        # outputs are per-lane scalars, so XLA reports the donation unused —
        # expected, not actionable
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        if aot is not None and sharding is None:
            best, final, it = aot.call(
                fn, ("dual", "donated" if donate else "plain"),
                args, static_kw)
        else:
            best, final, it = fn(*args, **static_kw)
    if not block:
        return DualBatchResult(best, final, it)
    return DualBatchResult(np.asarray(best), np.asarray(final),
                           np.asarray(it))


def solve_dual_demgrad_batch(caps, dems, *, n_valid=None, iters: int = 800,
                             lr: float = 0.08, tol: float = 0.0,
                             check_every: int = 25, use_pallas: bool = False,
                             interpret: bool | None = None,
                             backend: str | None = None, aot=None,
                             sharding=None, donate: bool = False,
                             block: bool = True, d_max: int | None = None,
                             mean_degree: float | None = None,
                             max_rounds: int | None = None
                             ) -> DualDemgradBatchResult:
    """``solve_dual_batch`` + per-instance demand gradients — the
    adversarial-traffic search's inner solve.

    Identical batching/padding/sharding/donation semantics (see
    ``solve_dual_batch``); the extra output ``dem_grad[B, N, N]`` is the
    Danskin gradient of each instance's converged log-ratio bound w.r.t.
    its demand matrix (see ``DualDemgradBatchResult``).  One extra APSP
    forward per instance, no APSP backward.
    """
    interpret = kops.resolve_interpret(interpret)
    backend = normalize_backend(backend, use_pallas)
    if len(caps) != len(dems):
        raise ValueError(f"caps ({len(caps)}) and dems ({len(dems)}) "
                         "must have equal length")
    if len(caps) == 0:
        z = np.zeros(0, np.float32)
        return DualDemgradBatchResult(z, z.copy(), np.zeros(0, np.int32),
                                      np.zeros((0, 0, 0), np.float32))
    if not isinstance(caps, (np.ndarray, jax.Array)):
        caps = np.stack([as_cap(c) for c in caps])
    if not isinstance(dems, (np.ndarray, jax.Array)):
        dems = np.stack([np.asarray(d) for d in dems])
    if n_valid is None:
        n_valid = np.full(caps.shape[0], caps.shape[1], np.int32)
    backend, d_max = resolve_backend_density(
        backend, caps, n=caps.shape[1], d_max=d_max,
        mean_degree=mean_degree)
    capj = jnp.asarray(caps, jnp.float32)
    demj = jnp.asarray(dems, jnp.float32)
    nvj = jnp.asarray(n_valid, jnp.int32)
    if sharding is not None:
        capj, demj, nvj = jax.device_put((capj, demj, nvj), sharding)
    fn = _solve_demgrad_batch_donated if donate else _solve_demgrad_batch
    args = (capj, demj, nvj, jnp.float32(lr), jnp.float32(tol))
    static_kw = dict(iters=iters, check_every=check_every,
                     backend=backend, interpret=interpret,
                     d_max=d_max, max_rounds=max_rounds)
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        if aot is not None and sharding is None:
            best, final, it, g = aot.call(
                fn, ("dual-demgrad", "donated" if donate else "plain"),
                args, static_kw)
        else:
            best, final, it, g = fn(*args, **static_kw)
    if not block:
        return DualDemgradBatchResult(best, final, it, g)
    return DualDemgradBatchResult(np.asarray(best), np.asarray(final),
                                  np.asarray(it), np.asarray(g))
