"""JAX maximum-concurrent-flow solver via dual (LP-duality) descent.

LP duality for max concurrent flow: with edge lengths l >= 0,

    theta* = min_l  sum_e c_e l_e  /  sum_{(s,t)} dem(s,t) * dist_l(s, t)

Every iterate gives a *certified upper bound* on theta* (scale l so the
demand-weighted distance is 1); at the optimum the bound is tight.  We
minimise the log-ratio with Adam in log-length space.  dist_l is all-pairs
shortest paths computed by O(log N) tropical-matmul squarings — the Pallas
kernel in repro.kernels.minplus on TPU — and JAX autodiff through the (min,+)
recursion yields shortest-path-DAG subgradients automatically.

This is the paper's CPLEX replacement that actually scales: it is pure
dense linear algebra, jit/vmap-able over topology batches (the paper's "20
runs per point" becomes one batched solve), and sharding the N x N distance
matrices over a mesh distributes the solve.

Validation: tests/test_flow.py checks the dual bound converges to the HiGHS
exact optimum within a few percent on paper-scale instances.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graphs import Topology, as_cap
from repro.kernels import ops as kops

__all__ = ["DualResult", "apsp", "solve_dual", "solve_dual_batch", "aspl"]

_INF = 1.0e18    # off-edge weight; survives log2(N) doublings in float32


@dataclasses.dataclass(frozen=True)
class DualResult:
    throughput_ub: float      # best certified dual bound on theta*
    final_ratio: float        # ratio at the last iterate (convergence probe)
    iterations: int


def _apsp_step(d: jax.Array, use_pallas: bool) -> jax.Array:
    if use_pallas:
        return jnp.minimum(d, kops.minplus_matmul(d, d, 128, True))
    return jnp.minimum(d, jnp.min(d[:, :, None] + d[None, :, :], axis=1))


def apsp(w: jax.Array, use_pallas: bool = False) -> jax.Array:
    """All-pairs shortest paths of a weighted adjacency matrix by repeated
    (min,+) squaring.  w: [N, N], _INF for non-edges, 0 diagonal."""
    n = w.shape[0]
    steps = max(1, math.ceil(math.log2(max(n - 1, 2))))
    d = w
    for _ in range(steps):
        d = _apsp_step(d, use_pallas)
    return d


def aspl(cap: Topology | np.ndarray | jax.Array,
         dem: np.ndarray | jax.Array | None = None,
         use_pallas: bool = False) -> float:
    """Average shortest-path length in hops (demand-weighted if dem given)."""
    cap = jnp.asarray(as_cap(cap), jnp.float32)
    n = cap.shape[0]
    w = jnp.where(cap > 0, 1.0, _INF)
    w = jnp.where(jnp.eye(n, dtype=bool), 0.0, w)
    d = apsp(w, use_pallas)
    if dem is None:
        mask = (~jnp.eye(n, dtype=bool)) & (d < _INF / 2)
        return float(jnp.where(mask, d, 0.0).sum() / mask.sum())
    dem = jnp.asarray(dem, jnp.float32)
    return float((d * dem).sum() / dem.sum())


def _dual_ratio(z: jax.Array, cap: jax.Array, dem: jax.Array,
                edge_mask: jax.Array, eye: jax.Array,
                use_pallas: bool) -> tuple[jax.Array, jax.Array]:
    """Returns (log-ratio loss, certified bound D(l)/alpha(l))."""
    l = jnp.exp(z)
    w = jnp.where(edge_mask, l, _INF)
    w = jnp.where(eye, 0.0, w)
    dist = apsp(w, use_pallas)
    alpha = (dem * dist).sum()
    d_val = (cap * l * edge_mask).sum()
    ratio = d_val / alpha
    return jnp.log(d_val) - jnp.log(alpha), ratio


@functools.partial(jax.jit, static_argnames=("iters", "use_pallas"))
def _solve(cap: jax.Array, dem: jax.Array, iters: int, lr_peak: float,
           use_pallas: bool) -> tuple[jax.Array, jax.Array]:
    n = cap.shape[0]
    edge_mask = cap > 0
    eye = jnp.eye(n, dtype=bool)
    z0 = jnp.zeros((n, n), jnp.float32)

    loss_and_ratio = functools.partial(
        _dual_ratio, cap=cap, dem=dem, edge_mask=edge_mask, eye=eye,
        use_pallas=use_pallas)
    grad_fn = jax.value_and_grad(lambda z: loss_and_ratio(z), has_aux=True)

    def step(i, state):
        z, m, v, best = state
        (_, ratio), g = grad_fn(z)
        best = jnp.minimum(best, ratio)
        # Adam with cosine-decayed lr
        t = i + 1
        lr = lr_peak * 0.5 * (1 + jnp.cos(jnp.pi * i / iters)) + 1e-3
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        z = z - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return z, m, v, best

    init = (z0, jnp.zeros_like(z0), jnp.zeros_like(z0), jnp.float32(jnp.inf))
    z, _, _, best = jax.lax.fori_loop(0, iters, step, init)
    _, final_ratio = loss_and_ratio(z)
    best = jnp.minimum(best, final_ratio)
    return best, final_ratio


def solve_dual(cap: Topology | np.ndarray, dem: np.ndarray, *,
               iters: int = 800, lr: float = 0.08,
               use_pallas: bool = False) -> DualResult:
    """Certified upper bound on max-concurrent-flow throughput (converges to
    the exact value; see module docstring)."""
    best, final = _solve(jnp.asarray(as_cap(cap), jnp.float32),
                         jnp.asarray(dem, jnp.float32),
                         iters, lr, use_pallas)
    return DualResult(float(best), float(final), iters)


def solve_dual_batch(caps, dems, *, iters: int = 800,
                     lr: float = 0.08, use_pallas: bool = False) -> np.ndarray:
    """Batched solve over stacked [R, N, N] topologies/demands (the paper's
    '20 runs per data point' in a single vmapped program).  ``caps`` may be a
    stacked array or a sequence of Topologies/matrices of equal size."""
    if not isinstance(caps, (np.ndarray, jax.Array)):
        caps = np.stack([as_cap(c) for c in caps])
    if not isinstance(dems, (np.ndarray, jax.Array)):
        dems = np.stack([np.asarray(d) for d in dems])
    fn = jax.vmap(lambda c, d: _solve(c, d, iters, lr, use_pallas)[0])
    out = fn(jnp.asarray(caps, jnp.float32), jnp.asarray(dems, jnp.float32))
    return np.asarray(out)
