"""BatchPlan — the planning/execution core for batched dual solves.

Every figure in the paper is thousands of independent max-concurrent-flow
instances (20 runs per point, many points per figure, Figs. 3-7 are whole
grids).  This module turns one heterogeneous pile of (topology, demand)
instances into an explicit execution plan and runs it:

1. **Buckets** — instances are grouped by padded node count
   (``bucket_size``: pow2 / mult128 / fixed multiple / exact), and every
   member of a bucket is padded to the bucket's largest member, so an
   equal-size group (the per-figure common case) pads nothing.  Padded
   nodes carry zero capacity/demand and are masked out of the dual ratio
   (see ``repro.core.mcf``).
2. **Chunks** — each bucket's batch axis is split into chunks under a
   configurable lane budget (``max_lanes``), bounding device memory per
   launch and letting early-stopping chunks retire without waiting for the
   slowest lane of the whole bucket.  When a bucket needs several chunks
   they all share one lane count (the trailing chunk is padded with
   replicated lanes), so XLA compiles ONE program per (bucket, chunk-shape)
   — ``PlanStats.compile_keys`` lists exactly those shapes.
3. **Devices** — each chunk's batch axis is sharded across a 1-D
   ``jax.sharding.Mesh`` of ``devices`` local devices via ``NamedSharding``
   (the chunk lane count is always a device-count multiple; surplus lanes
   replicate a real instance and are dropped on unpack, so per-lane results
   are bit-identical to a single-device run).
4. **Async dispatch** — all chunks are dispatched without blocking
   (``solve_*_batch(..., block=False)`` donates the device input buffers
   and returns in-flight arrays); the host syncs ONCE at the end with
   ``jax.block_until_ready`` over the whole set, so devices overlap chunk
   execution instead of round-tripping per bucket.

A plan is solver-agnostic: ``execute(solver="dual")`` (the default) runs
the certified-upper-bound dual descent (``repro.core.mcf``) and
``execute(solver="primal")`` runs the Frank–Wolfe primal solver
(``repro.core.primal``, certified lower bound + the free dual bound) —
primal lanes ride exactly the same buckets/chunks/sharding as dual lanes.

``DualEngine``/``PrimalEngine``/``CertifiedEngine``/``AutoEngine``
(``repro.core.engine``) delegate their ``solve_batch`` here; ``run_sweeps``
routes entire figure families through one ``BatchPlan``; the fleet
optimizer (``repro.design``) re-executes the SAME plan structure every
search round via ``refill`` — new candidate wirings, identical
buckets/chunks/compile keys, so a whole multi-round search compiles each
solver once.  This seam is where multi-host dispatch, streaming sweeps,
and result caching plug in.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import numpy as np

from repro.core import aotcache, mcf, primal, routing
from repro.core.graphs import Topology, as_cap, degree_stats

__all__ = ["bucket_size", "device_count", "compile_cache_sizes", "Chunk",
           "PlanStats", "InstanceSolve", "SOLVERS", "BatchPlan"]


def bucket_size(n: int, mode: str | int | None) -> int:
    """Padded size for an ``n``-node instance under a bucketing ``mode``:
    ``"pow2"`` (next power of two, floor 8), ``"mult128"`` (next multiple
    of 128 — TPU tile-aligned), an ``int`` m (next multiple of m), or
    ``None``/``"none"``/``"exact"`` (no padding: group by exact size)."""
    if mode in (None, "none", "exact"):
        return n
    if mode == "pow2":
        return max(8, 1 << (n - 1).bit_length())
    if mode == "mult128":
        mode = 128
    if isinstance(mode, int) and mode > 0:
        return -(-n // mode) * mode
    raise ValueError(f"unknown bucket mode {mode!r}; expected 'pow2', "
                     "'mult128', a positive int, or None")


def device_count(devices: int | None = None) -> int:
    """Resolve a ``devices`` knob: ``None`` means every local device."""
    import jax
    avail = len(jax.local_devices())
    if devices is None:
        return avail
    if not 1 <= devices <= avail:
        raise ValueError(f"devices={devices} out of range; "
                         f"{avail} local device(s) available")
    return int(devices)


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One device launch: a slice of a bucket, padded to ``lanes`` rows."""

    bucket: int                # bucket key the members were grouped under
    padded_n: int              # node-dim target (largest member in bucket)
    indices: tuple[int, ...]   # original instance positions (real lanes)
    lanes: int                 # batch rows incl. padding (devices multiple)

    @property
    def pad_lanes(self) -> int:
        return self.lanes - len(self.indices)


@dataclasses.dataclass(frozen=True)
class PlanStats:
    """What the planner decided — reported in result ``meta`` and benches."""

    instances: int
    buckets: int
    chunks: int
    devices: int
    max_lanes: int | None
    lanes_total: int           # sum of chunk lane counts (incl. padding)
    lanes_padded: int          # replicated lanes added for shape/device fit
    compile_keys: tuple[tuple[int, int], ...]   # distinct (padded_n, lanes)

    def as_dict(self) -> dict[str, Any]:
        # compile_keys stays a tuple of tuples: immutable, still JSON-able
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class InstanceSolve:
    """Per-instance solver output of an executed plan (solver-agnostic).

    ``value`` is the solver's headline certified bound on the instance's
    θ* (per-unit-demand max concurrent flow rate): a certified UPPER
    bound under ``solver="dual"``, a certified LOWER bound under
    ``solver="primal"`` (whose free dual upper bound lands in
    ``meta["ub"]`` — the pair is a provable bracket).  Everything else
    the solver reports (dual: ``final_ratio``; primal: ``ub`` and
    ``final_util``) lands in ``meta`` alongside the plan placement.
    """

    value: float
    iterations: int
    meta: Mapping[str, Any]


def _dispatch_dual(capp, demp, n_valid, sharding, solver_kw):
    r = mcf.solve_dual_batch(capp, demp, n_valid=n_valid, sharding=sharding,
                             donate=True, block=False, **solver_kw)
    return {"value": r.throughput_ub, "final_ratio": r.final_ratio,
            "iterations": r.iterations}


def _dispatch_primal(capp, demp, n_valid, sharding, solver_kw):
    r = primal.solve_primal_batch(capp, demp, n_valid=n_valid,
                                  sharding=sharding, donate=True,
                                  block=False, **solver_kw)
    return {"value": r.throughput_lb, "ub": r.throughput_ub,
            "final_util": r.final_util, "iterations": r.iterations}


def _dispatch_dual_demgrad(capp, demp, n_valid, sharding, solver_kw):
    r = mcf.solve_dual_demgrad_batch(capp, demp, n_valid=n_valid,
                                     sharding=sharding, donate=True,
                                     block=False, **solver_kw)
    return {"value": r.throughput_ub, "final_ratio": r.final_ratio,
            "iterations": r.iterations, "dem_grad": r.dem_grad}


def _dispatch_ecmp(capp, demp, n_valid, sharding, solver_kw):
    r = routing.solve_ecmp_batch(capp, demp, n_valid=n_valid,
                                 sharding=sharding, donate=True,
                                 block=False, **solver_kw)
    return {"value": r.throughput_lb, "ub": r.throughput_ub,
            "final_util": r.final_util, "iterations": r.iterations}


def _dispatch_ksp(capp, demp, n_valid, sharding, solver_kw):
    r = routing.solve_ksp_batch(capp, demp, n_valid=n_valid,
                                sharding=sharding, donate=True,
                                block=False, **solver_kw)
    return {"value": r.throughput_lb, "ub": r.throughput_ub,
            "final_util": r.final_util, "iterations": r.iterations}


# chunk dispatchers by solver name: (capp, demp, n_valid, sharding,
# solver_kw) -> dict of in-flight per-lane arrays; "value" is the headline
# bound, every other key is copied into the per-instance meta
SOLVERS = {"dual": _dispatch_dual, "primal": _dispatch_primal,
           "dual-demgrad": _dispatch_dual_demgrad,
           "ecmp": _dispatch_ecmp, "ksp": _dispatch_ksp}


def compile_cache_sizes() -> dict[str, int | None]:
    """Compiled-program counts per (solver backend, entry point) — e.g.
    ``{"dual.solve_batch": 3, "primal.solve_batch": 1, ...}``.  Benchmarks
    report deltas of this to show "one compile per (bucket, chunk-shape)";
    ``None`` = the installed jax lacks cache introspection.  Also carries
    the persistent AOT cache counters (``aot.compiles`` / ``aot.hits``,
    always-present ints — zero when the cache is off) so warm-run checks
    can assert "no new XLA compiles" across processes."""
    out: dict[str, int | None] = {}
    for name, mod in (("dual", mcf), ("primal", primal),
                      ("routing", routing)):
        for k, v in mod.compile_cache_sizes().items():
            out[f"{name}.{k}"] = v
    a = aotcache.stats()
    out["aot.compiles"] = a["compiles"]
    out["aot.hits"] = a["hits"]
    return out


class BatchPlan:
    """An executable plan over one pile of (topology, demand) instances."""

    def __init__(self, caps: list[np.ndarray], dems: list[np.ndarray],
                 chunks: list[Chunk], devices: int,
                 max_lanes: int | None, bucket_mode: str | int | None):
        self.caps = caps
        self.dems = dems
        self.chunks = chunks
        self.devices = devices
        self.max_lanes = max_lanes
        self.bucket_mode = bucket_mode
        self.stats = PlanStats(
            instances=len(caps), buckets=len({c.bucket for c in chunks}),
            chunks=len(chunks), devices=devices, max_lanes=max_lanes,
            lanes_total=sum(c.lanes for c in chunks),
            lanes_padded=sum(c.pad_lanes for c in chunks),
            compile_keys=tuple(sorted({(c.padded_n, c.lanes)
                                       for c in chunks})))

    @classmethod
    def build(cls, topos: Sequence[Topology | np.ndarray],
              dems: Sequence[np.ndarray], *,
              bucket: str | int | None = "pow2",
              max_lanes: int | None = None,
              devices: int | None = None) -> "BatchPlan":
        """Plan ``len(topos)`` instances: bucket by padded size, chunk each
        bucket under ``max_lanes`` rows per launch, pad each chunk's batch
        axis to a multiple of ``devices``.  Every launch spans all devices,
        so one lane per device is the floor: a ``max_lanes`` below the
        device count (or not a multiple of it) is rounded to the nearest
        feasible budget, never silently exceeded beyond that floor."""
        if len(topos) != len(dems):
            raise ValueError(f"topos ({len(topos)}) and dems ({len(dems)}) "
                             "must have equal length")
        if max_lanes is not None and max_lanes < 1:
            raise ValueError(f"max_lanes must be >= 1, got {max_lanes}")
        caps = [np.asarray(as_cap(t), np.float32) for t in topos]
        demsl = [np.asarray(d, np.float32) for d in dems]
        ndev = device_count(devices)
        by_bucket: dict[int, list[int]] = {}
        for i, c in enumerate(caps):
            by_bucket.setdefault(bucket_size(c.shape[0], bucket),
                                 []).append(i)
        chunks: list[Chunk] = []
        for bkt, idx in sorted(by_bucket.items()):
            # pad to the largest member, not the bucket ceiling: same one
            # compile per (bucket, chunk-shape), but an equal-size group
            # pads no nodes at all
            size = max(caps[i].shape[0] for i in idx)
            need = -(-len(idx) // ndev) * ndev   # device multiple that fits
            if max_lanes is None:
                lanes = need
            else:
                # floor the budget to a device multiple (never below one
                # lane per device), and never pad a small bucket up to it
                lanes = min(max(ndev, max_lanes // ndev * ndev), need)
            for lo in range(0, len(idx), lanes):
                chunks.append(Chunk(bucket=bkt, padded_n=size,
                                    indices=tuple(idx[lo:lo + lanes]),
                                    lanes=lanes))
        return cls(caps, demsl, chunks, ndev, max_lanes, bucket)

    def refill(self, topos: Sequence[Topology | np.ndarray],
               dems: Sequence[np.ndarray]) -> "BatchPlan":
        """A new plan over fresh instances that reuses THIS plan's chunk
        structure (same buckets, chunk shapes, device layout — so exactly
        the same XLA compile keys, guaranteed structurally rather than by
        re-planning and hoping).  The new pile must match instance-for-
        instance: same length, and instance ``i`` must have the same node
        count as before (``ValueError`` otherwise — fall back to
        ``build``).  This is the fleet-search fast path: a stochastic
        optimizer proposing same-size candidate wirings every round pays
        the planner cost once and zero recompiles after round one."""
        if len(topos) != len(self.caps):
            raise ValueError(f"refill needs {len(self.caps)} instances "
                             f"(the planned count), got {len(topos)}")
        caps = [np.asarray(as_cap(t), np.float32) for t in topos]
        for i, (old, new) in enumerate(zip(self.caps, caps)):
            if old.shape != new.shape:
                raise ValueError(
                    f"refill instance {i} is {new.shape[0]} nodes, planned "
                    f"for {old.shape[0]}; rebuild the plan for a new size "
                    "profile")
        demsl = [np.asarray(d, np.float32) for d in dems]
        clone = object.__new__(type(self))
        clone.__dict__.update(self.__dict__)
        clone.caps = caps
        clone.dems = demsl
        return clone

    def _sharding(self):
        """NamedSharding of the batch axis over a 1-D device mesh (or None
        on a single-device plan — computation stays on the default device)."""
        if self.devices <= 1:
            return None
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((self.devices,), ("batch",),
                             devices=jax.local_devices()[:self.devices])
        return NamedSharding(mesh, P("batch"))

    def _pack(self, chunk: Chunk):
        """Materialise one chunk's padded [lanes, n, n] arrays.  Surplus
        lanes replicate the chunk's first instance (never a zero instance:
        a 0/0 dual ratio would poison the lane with NaNs) and are dropped
        on unpack."""
        s = chunk.padded_n
        capp = np.zeros((chunk.lanes, s, s), np.float32)
        demp = np.zeros((chunk.lanes, s, s), np.float32)
        n_valid = np.empty(chunk.lanes, np.int32)
        rows = list(chunk.indices) + [chunk.indices[0]] * chunk.pad_lanes
        for lane, i in enumerate(rows):
            n = self.caps[i].shape[0]
            capp[lane, :n, :n] = self.caps[i]
            demp[lane, :n, :n] = self.dems[i]
            n_valid[lane] = n
        return capp, demp, n_valid

    def _density_hints(self, chunk: Chunk) -> dict[str, Any]:
        """Per-chunk sparsity stats from the UNPADDED member instances, so
        the batch solvers' host-side ``resolve_backend_density`` never has
        to scan the padded [lanes, n, n] stack: the ell-bf table width is
        the widest member's max degree, and the density gate uses the
        densest member's mean degree (sparse only when every lane is)."""
        d_max, mean = 0, 0.0
        for i in chunk.indices:
            dm, md = degree_stats(self.caps[i])
            d_max = max(d_max, dm)
            mean = max(mean, md)
        return {"d_max": max(1, d_max), "mean_degree": mean}

    def execute(self, solver: str = "dual",
                **solver_kw) -> list[InstanceSolve]:
        """Dispatch every chunk asynchronously (sharded over the plan's
        devices), sync once, and scatter per-instance results back into
        input order.  ``solver`` picks the batch solver (``SOLVERS``:
        "dual", "primal", "dual-demgrad" — the latter additionally
        returns each lane's demand gradient in ``meta["dem_grad"]`` —
        or the routing-restricted "ecmp" / "ksp" lower-bound programs);
        ``solver_kw`` goes to its ``solve_*_batch``
        (iters/lr/tol/check_every/use_pallas/interpret/backend/d_max/
        max_rounds).  When the backend can land on ``"ell-bf"`` and the
        caller gave no explicit table stats, each chunk gets density hints
        computed from its own unpadded members (``_density_hints``)."""
        import jax
        try:
            dispatch = SOLVERS[solver]
        except KeyError:
            raise ValueError(f"unknown plan solver {solver!r}; "
                             f"known: {sorted(SOLVERS)}") from None
        sharding = self._sharding()
        want_hints = (solver_kw.get("backend") in (None, "auto", "ell-bf")
                      and not solver_kw.get("use_pallas")
                      and "d_max" not in solver_kw
                      and "mean_degree" not in solver_kw)
        pending = []
        for chunk in self.chunks:
            capp, demp, n_valid = self._pack(chunk)
            kw = ({**solver_kw, **self._density_hints(chunk)}
                  if want_hints else solver_kw)
            pending.append(dispatch(capp, demp, n_valid, sharding, kw))
        # ONE host sync for the whole plan: chunks overlap on-device while
        # the host is still packing/dispatching later ones
        jax.block_until_ready([list(r.values()) for r in pending])
        stats = self.stats.as_dict()   # values immutable; copied per result
        out: list[InstanceSolve | None] = [None] * len(self.caps)
        for ci, (chunk, res) in enumerate(zip(self.chunks, pending)):
            arrs = {k: np.asarray(v) for k, v in res.items()}
            for lane, i in enumerate(chunk.indices):
                # per-lane scalars become floats (iterations: int); non-
                # scalar per-lane outputs (e.g. the dual-demgrad solver's
                # [n, n] demand gradient) stay np arrays, cropped back to
                # the instance's unpadded node count
                n = int(self.caps[i].shape[0])
                solved = {}
                for k, a in arrs.items():
                    if k == "value":
                        continue
                    if k == "iterations":
                        solved[k] = int(a[lane])
                    elif a[lane].ndim == 0:
                        solved[k] = float(a[lane])
                    else:
                        solved[k] = np.asarray(a[lane])[tuple(
                            slice(n) for _ in range(a[lane].ndim))]
                out[i] = InstanceSolve(
                    value=float(arrs["value"][lane]),
                    iterations=int(arrs["iterations"][lane]),
                    meta={**solved,
                          "bucket": chunk.bucket,
                          "padded_n": chunk.padded_n,
                          "nodes": int(self.caps[i].shape[0]),
                          "batch_size": len(chunk.indices),
                          "chunk": ci, "chunks": len(self.chunks),
                          "devices": self.devices, "plan": dict(stats)})
        return out  # type: ignore[return-value]
