"""Primal Frank–Wolfe max-concurrent-flow solver: certified LOWER bounds.

The dual solver (``repro.core.mcf``) certifies only an *upper* bound on the
max concurrent flow throughput theta*.  This module constructs an explicit
feasible flow and certifies a *lower* bound, closing the bracket — at any
scale, not just where the exact LP is tractable.

How it works:

* **Linearized subproblem = shortest-path routing.**  The Frank–Wolfe
  linear minimization oracle of concurrent-flow routing under edge lengths
  ``l`` is all-or-nothing shortest-path routing: send every demand along
  its l-shortest paths.  Those loads come from ONE vjp through the same
  APSP the dual uses (``repro.core.apsp``'s shared custom VJP is the
  shortest-path-DAG subgradient, ties split evenly, identical on every
  ``ApspBackend``):
  ``loads_e = d alpha(l) / d l_e`` where ``alpha = sum dem * dist_l``.
  Each per-pair contribution is a convex combination of that pair's
  shortest paths, so ``loads`` is a valid fractional routing of the FULL
  demand matrix.
* **Lengths ride the dual descent.**  The iterate's edge lengths are the
  same Adam-on-log-ratio trajectory the dual solver runs; as they approach
  dual-optimal, the shortest-path oracle concentrates on tight edges.  One
  APSP forward + one APSP backward per iteration yields BOTH the dual step
  and the FW direction — every primal solve carries the dual upper bound
  for free (``throughput_ub``), which is what lets
  ``get_engine("certified")`` attach an (lb, ub, gap) bracket from one
  fused program through one ``BatchPlan``.
* **FW step with exact line search.**  ``loads <- (1-g) loads + g sp``
  with ``g`` from a ternary search on the max utilization (convex
  piecewise-linear in ``g``), floored at ``1/(t+1)`` so the averaging
  never stalls at a nonsmooth kink.
* **The certificate.**  Every iterate is a convex combination of routings
  that each carry the full demand, so ``loads / max_util`` is a feasible
  concurrent flow at rate ``1 / max_util``: a certified lower bound.  An
  instance whose demand is not routable (a demanded pair disconnected)
  reports ``lb = 0``.

Batching, padding (``n_valid`` masking), early stopping, ``interpret``
auto-detection, and the donated/sharded/async entry points all mirror
``repro.core.mcf`` — ``repro.core.plan.BatchPlan`` drives this solver
through the same buckets/chunks/device sharding as the dual
(``solver="primal"``).

Validation: tests/test_conformance.py asserts ``lb <= theta_exact <= ub``
with bracket gap < 5% across traffic patterns x topology families.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.apsp import normalize_backend
from repro.core.graphs import Topology, as_cap
from repro.core.mcf import (_INF, apsp, jit_cache_size,
                            resolve_backend_density)
from repro.kernels import ops as kops

__all__ = ["PrimalResult", "PrimalBatchResult", "solve_primal",
           "solve_primal_batch", "compile_cache_sizes"]

_LS_STEPS = 24   # ternary-search iterations: (2/3)^24 ~ 6e-5 gamma resolution


@dataclasses.dataclass(frozen=True)
class PrimalResult:
    """One instance's primal solve: a certified LOWER bound on θ* (an
    explicit feasible flow routes every demand at this per-unit-demand
    rate) plus the driving dual descent's free UPPER bound — together a
    provable bracket ``throughput_lb`` ≤ θ* ≤ ``throughput_ub``."""

    throughput_lb: float      # certified lower bound (explicit feasible flow)
    throughput_ub: float      # dual bound from the driving descent (free)
    final_util: float         # max edge utilization of the last averaged flow
    iterations: int           # descent steps actually executed (<= cap)

    @property
    def gap(self) -> float:
        """Relative bracket width (ub - lb) / ub."""
        return (self.throughput_ub - self.throughput_lb) / \
            max(self.throughput_ub, 1e-30)


@dataclasses.dataclass(frozen=True)
class PrimalBatchResult:
    """Per-instance outputs of one batched primal solve.  Indexing and
    iteration yield the certified lower bounds (``throughput_lb``); a
    ``block=False`` solve carries in-flight ``jax.Array``s (sync with
    ``jax.block_until_ready``)."""

    throughput_lb: np.ndarray   # [B] certified lower bound per instance
    throughput_ub: np.ndarray   # [B] dual bound of the driving descent
    final_util: np.ndarray      # [B] max utilization at the last iterate
    iterations: np.ndarray      # [B] descent steps executed per instance

    def __len__(self) -> int:
        return len(self.throughput_lb)

    def __getitem__(self, i):
        return self.throughput_lb[i]

    def __iter__(self):
        return iter(self.throughput_lb)


def _solve_one(cap: jax.Array, dem: jax.Array, n_valid: jax.Array,
               lr_peak: jax.Array, tol: jax.Array, *, iters: int,
               check_every: int, backend: str, interpret: bool,
               d_max: int | None = None, max_rounds: int | None = None
               ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One (possibly padded) instance: nodes >= n_valid are masked out.

    Early stopping: every ``check_every`` steps, stop once the bracket gap
    (ub - lb) / ub shrank by less than ``tol`` over the window (the gap is
    monotone non-increasing, so ``tol=0`` never stops early).  All state
    updates go through the ``lax.while_loop`` carry, so under ``vmap``
    converged lanes hold their state while the rest keep descending.

    Returns (best lb, best ub, final max utilization, iterations).
    """
    nmax = cap.shape[0]
    node_mask = jnp.arange(nmax) < n_valid
    pair_mask = node_mask[:, None] & node_mask[None, :]
    cap = jnp.where(pair_mask, cap, 0.0)
    dem = jnp.where(pair_mask, dem, 0.0)
    edge_mask = (cap > 0) & pair_mask
    eye = jnp.eye(nmax, dtype=bool)
    safe_cap = jnp.where(edge_mask, cap, 1.0)

    def alpha_of(l):
        w = jnp.where(edge_mask, l, _INF)
        w = jnp.where(eye, 0.0, w)
        dist = apsp(w, backend, interpret, d_max, max_rounds)
        return (dem * jnp.where(pair_mask, dist, 0.0)).sum()

    def umax_of(loads):
        return jnp.max(jnp.where(edge_mask, loads / safe_cap, 0.0))

    def lb_of(umax):
        return jnp.where(umax > 0, 1.0 / jnp.maximum(umax, 1e-30), 0.0)

    # a demanded pair with no path makes the flow unroutable: theta* = 0
    routable = alpha_of(jnp.ones_like(cap)) < _INF / 2

    def cond(state):
        i = state[0]
        done = state[-1]
        return (i < iters) & ~done

    def step(state):
        i, z, m, v, loads, best_lb, best_ub, ref_gap, _ = state
        l = jnp.exp(z)
        alpha, vjp = jax.vjp(alpha_of, l)
        (g_alpha,) = vjp(jnp.ones_like(alpha))
        sp = jnp.where(edge_mask, g_alpha, 0.0)   # FW direction: SP loads
        d_val = (cap * l).sum()
        best_ub = jnp.minimum(best_ub, d_val / alpha)

        # dual Adam step on log D(l) - log alpha(l); d/dz = l * d/dl
        g = l * (cap / d_val - sp / alpha)
        t = i + 1
        lr = lr_peak * 0.5 * (1 + jnp.cos(jnp.pi * i / iters)) + 1e-3
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.999 ** t)
        z = z - lr * mh / (jnp.sqrt(vh) + 1e-8)

        # FW blend: exact ternary line search on the max utilization.
        # Hoist the two per-edge utilization arrays so each of the 2 *
        # _LS_STEPS evaluations is one fused blend + max, not a fresh
        # masked divide (utilization is linear in the flow, so blending
        # pre-divided arrays is the same function of gamma).
        u_cur = jnp.where(edge_mask, loads / safe_cap, 0.0)
        u_sp = jnp.where(edge_mask, sp / safe_cap, 0.0)

        def blended_umax(gam):
            return jnp.max((1 - gam) * u_cur + gam * u_sp)

        lo, hi = jnp.float32(0.0), jnp.float32(1.0)
        for _ in range(_LS_STEPS):
            m1 = lo + (hi - lo) / 3
            m2 = hi - (hi - lo) / 3
            f1 = blended_umax(m1)
            f2 = blended_umax(m2)
            lo = jnp.where(f1 < f2, lo, m1)
            hi = jnp.where(f1 < f2, m2, hi)
        gamma = jnp.maximum((lo + hi) / 2, 1.0 / (t + 1.0))
        gamma = jnp.where(i == 0, 1.0, gamma)   # first step adopts sp fully
        loads = (1 - gamma) * loads + gamma * sp
        best_lb = jnp.maximum(best_lb, lb_of(blended_umax(gamma)))

        at_check = t % check_every == 0
        gap = (best_ub - best_lb) / jnp.maximum(best_ub, 1e-30)
        done = at_check & (ref_gap - gap < tol)
        ref_gap = jnp.where(at_check, gap, ref_gap)
        return t, z, m, v, loads, best_lb, best_ub, ref_gap, done

    z0 = jnp.zeros((nmax, nmax), jnp.float32)
    init = (jnp.int32(0), z0, jnp.zeros_like(z0), jnp.zeros_like(z0),
            jnp.zeros_like(cap), jnp.float32(0.0), jnp.float32(jnp.inf),
            jnp.float32(jnp.inf), jnp.bool_(False))
    it, _, _, _, loads, best_lb, best_ub, _, _ = \
        jax.lax.while_loop(cond, step, init)
    best_lb = jnp.where(routable, best_lb, 0.0)
    return best_lb, best_ub, umax_of(loads), it


# compile-key statics, kept identical to the dual solver's so primal and
# dual lanes share one AOT-cache key scheme (d_max/max_rounds are the
# ell-bf table width and relaxation-round cap)
_STATIC = ("iters", "check_every", "backend", "interpret", "d_max",
           "max_rounds")


@functools.partial(jax.jit, static_argnames=_STATIC)
def _solve(cap, dem, n_valid, lr_peak, tol, *, iters, check_every,
           backend, interpret, d_max=None, max_rounds=None):
    return _solve_one(cap, dem, n_valid, lr_peak, tol, iters=iters,
                      check_every=check_every, backend=backend,
                      interpret=interpret, d_max=d_max,
                      max_rounds=max_rounds)


def _solve_batch_impl(caps, dems, n_valid, lr_peak, tol, *, iters,
                      check_every, backend, interpret, d_max=None,
                      max_rounds=None):
    fn = functools.partial(_solve_one, iters=iters, check_every=check_every,
                           backend=backend, interpret=interpret,
                           d_max=d_max, max_rounds=max_rounds)
    return jax.vmap(fn, in_axes=(0, 0, 0, None, None))(
        caps, dems, n_valid, lr_peak, tol)


_solve_batch = jax.jit(_solve_batch_impl, static_argnames=_STATIC)
_solve_batch_donated = jax.jit(_solve_batch_impl, static_argnames=_STATIC,
                               donate_argnums=(0, 1))


def compile_cache_sizes() -> dict[str, int | None]:
    """Compiled program variants per primal entry point (mirrors
    ``mcf.compile_cache_sizes``; ``None`` = introspection unavailable)."""
    return {"solve": jit_cache_size(_solve),
            "solve_batch": jit_cache_size(_solve_batch,
                                          _solve_batch_donated)}


def solve_primal(cap: Topology | np.ndarray, dem: np.ndarray, *,
                 iters: int = 800, lr: float = 0.08, tol: float = 0.0,
                 check_every: int = 25, use_pallas: bool = False,
                 interpret: bool | None = None,
                 backend: str | None = None, aot=None,
                 d_max: int | None = None,
                 max_rounds: int | None = None) -> PrimalResult:
    """Certified lower bound on max-concurrent-flow throughput from an
    explicit feasible flow (plus the driving dual descent's upper bound —
    see module docstring).  ``cap``: a ``Topology`` or symmetric [N, N]
    capacity matrix; ``dem``: [N, N] demand — both in base line-speed
    units, so the (lb, ub) bracket is around the paper's dimensionless
    per-unit-demand θ*.  ``tol > 0`` stops early once the bracket gap's
    shrinkage per ``check_every``-step window drops below it.  ``backend``
    picks the APSP backend (``use_pallas=True`` aliases "squaring-pallas");
    ``aot`` is accepted for parity with the batch entry point and
    ignored."""
    del aot
    interpret = kops.resolve_interpret(interpret)
    cap_host = as_cap(cap)
    backend, d_max = resolve_backend_density(
        normalize_backend(backend, use_pallas), cap_host,
        n=cap_host.shape[0], d_max=d_max)
    capj = jnp.asarray(cap_host, jnp.float32)
    lb, ub, util, it = _solve(
        capj, jnp.asarray(dem, jnp.float32), jnp.int32(capj.shape[0]),
        jnp.float32(lr), jnp.float32(tol), iters=iters,
        check_every=check_every, backend=backend, interpret=interpret,
        d_max=d_max, max_rounds=max_rounds)
    return PrimalResult(float(lb), float(ub), float(util), int(it))


def solve_primal_batch(caps, dems, *, n_valid=None, iters: int = 800,
                       lr: float = 0.08, tol: float = 0.0,
                       check_every: int = 25, use_pallas: bool = False,
                       interpret: bool | None = None,
                       backend: str | None = None, aot=None,
                       sharding=None, donate: bool = False,
                       block: bool = True, d_max: int | None = None,
                       mean_degree: float | None = None,
                       max_rounds: int | None = None) -> PrimalBatchResult:
    """Batched primal solve over stacked [R, N, N] topologies/demands; the
    call surface mirrors ``mcf.solve_dual_batch`` exactly (``n_valid``
    padding masks, ``sharding``/``donate``/``block`` for the ``BatchPlan``
    async path), so primal lanes ride the same buckets/chunks/device
    sharding as dual lanes.  ``backend``/``aot`` mirror the dual too
    (APSP backend registry; persistent AOT compile cache)."""
    interpret = kops.resolve_interpret(interpret)
    backend = normalize_backend(backend, use_pallas)
    if len(caps) != len(dems):
        raise ValueError(f"caps ({len(caps)}) and dems ({len(dems)}) "
                         "must have equal length")
    if len(caps) == 0:
        z = np.zeros(0, np.float32)
        return PrimalBatchResult(z, z.copy(), z.copy(),
                                 np.zeros(0, np.int32))
    if not isinstance(caps, (np.ndarray, jax.Array)):
        caps = np.stack([as_cap(c) for c in caps])
    if not isinstance(dems, (np.ndarray, jax.Array)):
        dems = np.stack([np.asarray(d) for d in dems])
    if n_valid is None:
        n_valid = np.full(caps.shape[0], caps.shape[1], np.int32)
    backend, d_max = resolve_backend_density(
        backend, caps, n=caps.shape[1], d_max=d_max,
        mean_degree=mean_degree)
    capj = jnp.asarray(caps, jnp.float32)
    demj = jnp.asarray(dems, jnp.float32)
    nvj = jnp.asarray(n_valid, jnp.int32)
    if sharding is not None:
        capj, demj, nvj = jax.device_put((capj, demj, nvj), sharding)
    fn = _solve_batch_donated if donate else _solve_batch
    args = (capj, demj, nvj, jnp.float32(lr), jnp.float32(tol))
    static_kw = dict(iters=iters, check_every=check_every,
                     backend=backend, interpret=interpret,
                     d_max=d_max, max_rounds=max_rounds)
    with warnings.catch_warnings():
        # outputs are per-lane scalars, so XLA reports the donation unused
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        if aot is not None and sharding is None:
            lb, ub, util, it = aot.call(
                fn, ("primal", "donated" if donate else "plain"),
                args, static_kw)
        else:
            lb, ub, util, it = fn(*args, **static_kw)
    if not block:
        return PrimalBatchResult(lb, ub, util, it)
    return PrimalBatchResult(np.asarray(lb), np.asarray(ub),
                             np.asarray(util), np.asarray(it))
