"""Exact maximum concurrent flow via scipy's HiGHS LP solver (paper §3).

The paper measures topology capacity as the solution of the standard maximum
concurrent multicommodity flow problem (CPLEX).  We reproduce it exactly with
the bundled HiGHS solver, using the standard per-*source* commodity
aggregation: all flows sharing a source s are one single-source flow variable
vector f_s[e] whose divergence at each node v is θ·dem[s, v] (and
−θ·Σ_v dem[s, v] at s).  Flow decomposition of a single-source flow shows this
is exact for concurrent flow — every path starts at s, so the per-sink
delivery is pinned at θ·dem[s, t].

This reduces the commodity count from O(N²) to ≤ N and is what makes
paper-scale instances (N ≈ 40–200) solve in seconds.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.optimize
import scipy.sparse as sp

from repro.core.graphs import Topology, as_cap

__all__ = ["FlowResult", "max_concurrent_flow", "aspl_hops", "edge_list"]


@dataclasses.dataclass(frozen=True)
class FlowResult:
    throughput: float          # θ: per-unit-demand concurrent rate
    edges: np.ndarray          # [E, 2] directed edge endpoints (u, v)
    edge_cap: np.ndarray       # [E] capacity per directed edge
    edge_flow: np.ndarray      # [E] total flow per directed edge at optimum
    status: str

    @property
    def utilization(self) -> np.ndarray:
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(self.edge_cap > 0, self.edge_flow / self.edge_cap, 0.0)

    @property
    def mean_utilization(self) -> float:
        """Capacity-weighted network utilisation U = Σf / Σc."""
        return float(self.edge_flow.sum() / self.edge_cap.sum())


def edge_list(cap: Topology | np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Directed edges (both directions) from a symmetric capacity matrix."""
    cap = as_cap(cap)
    us, vs = np.nonzero(cap)
    edges = np.stack([us, vs], axis=1)
    return edges, cap[us, vs].astype(np.float64)


def max_concurrent_flow(cap: Topology | np.ndarray, dem: np.ndarray,
                        want_flows: bool = True) -> FlowResult:
    """Solve max θ s.t. a multicommodity flow routes θ·dem concurrently.

    cap: Topology or [N, N] symmetric capacity matrix.
    dem: [N, N] demand matrix (dem[u, v] = flow volume u -> v at θ = 1).
    """
    cap = as_cap(cap)
    n = cap.shape[0]
    edges, ecap = edge_list(cap)
    ne = len(edges)
    if ne == 0 or dem.sum() == 0:
        raise ValueError("empty network or empty demand")

    sources = np.flatnonzero(dem.sum(axis=1) > 0)
    ns = len(sources)
    nvar = 1 + ns * ne          # [theta, f_{s0,e0..}, f_{s1,..}, ...]

    # --- equality: conservation per (source, node v != source) -------------
    rows, cols, vals = [], [], []
    rhs_rows = 0
    row_of = {}
    for si, s in enumerate(sources):
        for v in range(n):
            if v == s:
                continue            # redundant row (flows sum to zero)
            row_of[(si, v)] = rhs_rows
            rhs_rows += 1
    # incidence entries
    for si, s in enumerate(sources):
        base = 1 + si * ne
        for ei, (u, v) in enumerate(edges):
            if v != s:
                rows.append(row_of[(si, v)])
                cols.append(base + ei)
                vals.append(1.0)     # edge into v
            if u != s:
                rows.append(row_of[(si, u)])
                cols.append(base + ei)
                vals.append(-1.0)    # edge out of u
    # theta column: -dem[s, v]
    for si, s in enumerate(sources):
        for v in range(n):
            if v == s:
                continue
            d = dem[s, v]
            if d != 0:
                rows.append(row_of[(si, v)])
                cols.append(0)
                vals.append(-float(d))
    a_eq = sp.coo_matrix((vals, (rows, cols)), shape=(rhs_rows, nvar)).tocsc()
    b_eq = np.zeros(rhs_rows)

    # --- inequality: capacity per directed edge ----------------------------
    rows, cols, vals = [], [], []
    for si in range(ns):
        base = 1 + si * ne
        rows.extend(range(ne))
        cols.extend(range(base, base + ne))
        vals.extend([1.0] * ne)
    a_ub = sp.coo_matrix((vals, (rows, cols)), shape=(ne, nvar)).tocsc()
    b_ub = ecap.copy()

    c = np.zeros(nvar)
    c[0] = -1.0                     # maximise theta

    res = scipy.optimize.linprog(
        c, A_ub=a_ub, b_ub=b_ub, A_eq=a_eq, b_eq=b_eq,
        bounds=[(0, None)] * nvar, method="highs")
    if not res.success:
        raise RuntimeError(f"LP failed: {res.message}")

    theta = float(res.x[0])
    if want_flows:
        f = res.x[1:].reshape(ns, ne)
        edge_flow = f.sum(axis=0)
    else:
        edge_flow = np.zeros(ne)
    return FlowResult(throughput=theta, edges=edges, edge_cap=ecap,
                      edge_flow=edge_flow, status=res.message)


def aspl_hops(cap: Topology | np.ndarray,
              dem: np.ndarray | None = None) -> float:
    """Average shortest path length in hops.  If ``dem`` is given, the average
    is demand-weighted (the paper's ⟨D⟩ for a traffic matrix); otherwise it is
    over all connected ordered pairs."""
    import scipy.sparse.csgraph as csgraph

    cap = as_cap(cap)
    adj = sp.csr_matrix((cap > 0).astype(np.float64))
    dist = csgraph.shortest_path(adj, method="D", unweighted=True)
    if dem is None:
        mask = np.isfinite(dist) & ~np.eye(cap.shape[0], dtype=bool)
        return float(dist[mask].mean())
    w = dem / dem.sum()
    if not np.all(np.isfinite(dist[dem > 0])):
        return float("inf")
    return float((dist * w).sum())
