"""Throughput and path-length bounds (paper §4, §6.2).

* ``aspl_lower_bound`` — Cerf–Cowan–Mullin–Stanton Moore-style lower bound d*
  on the average shortest path length of any r-regular graph on N nodes.
* ``throughput_upper_bound`` — Theorem 1: T ≤ N·r / (⟨D⟩·f), with ⟨D⟩ ≥ d*.
* ``het_throughput_upper_bound`` — Eqn (1): the two-cluster heterogeneous
  bound min{path-bound, cut-bound}.
* ``cut_threshold`` — C̄* below which throughput *must* drop (Fig. 10).

These are *analytic* UPPER bounds: closed-form, computable without building
(or solving) any topology, and valid for EVERY member of their graph class
— a different kind of claim from the solver engines' per-instance bounds.
Units follow the rest of the repo: capacities in multiples of the base
line-speed (1 = one 1GbE link, both directions counted — the paper's C and
C̄), path lengths in hops, throughput as the dimensionless per-unit-demand
rate θ, flow counts f in unit-demand flows.
"""
from __future__ import annotations


__all__ = [
    "aspl_lower_bound",
    "throughput_upper_bound",
    "het_throughput_upper_bound",
    "cut_threshold",
]


def aspl_lower_bound(n: int, r: int) -> float:
    """d* from [Cerf et al. 1974]:

        d* = ( sum_{j=1}^{k-1} j·r·(r-1)^{j-1} + k·R ) / (N - 1)
        R  = N - 1 - sum_{j=1}^{k-1} r·(r-1)^{j-1}  >= 0,  k largest such.

    Interpretation: in the best case the r-regular graph is a Moore tree from
    every vertex — r·(r-1)^{j-1} vertices at hop j; R leftover vertices sit at
    hop k."""
    if r < 2:
        raise ValueError("need r >= 2")
    if n <= 1:
        return 0.0
    total = 0.0       # vertices accounted for in the Moore tree
    weighted = 0.0    # sum of j * (#vertices at hop j)
    k = 1
    while True:
        at_j = r * (r - 1) ** (k - 1)
        if total + at_j >= n - 1:
            break
        total += at_j
        weighted += k * at_j
        k += 1
    R = (n - 1) - total
    weighted += k * R
    return weighted / (n - 1)


def throughput_upper_bound(n: int, r: int, f: float,
                           aspl: float | None = None) -> float:
    """Theorem 1 (+ Cerf bound): per-flow throughput θ of ANY r-regular
    topology on n switches (r unit-capacity links each) carrying f
    unit-demand flows is at most n·r/(⟨D⟩·f); with ⟨D⟩ (hops) unknown,
    substituting the lower bound d* keeps it a valid certified upper
    bound on every such topology at once."""
    d = aspl if aspl is not None else aspl_lower_bound(n, r)
    if f <= 0:
        return float("inf")
    return n * r / (d * f)


def het_throughput_upper_bound(total_capacity: float, cut_capacity: float,
                               aspl: float, n1: int, n2: int) -> float:
    """Eqn (1): T <= min{ C/(⟨D⟩·(n1+n2)), C̄·(n1+n2)/(2·n1·n2) } for random
    permutation traffic over n1 (resp. n2) servers in cluster 1 (resp. 2).

    ``total_capacity``/``cut_capacity`` count both directions (paper's C, C̄);
    ``aspl`` is the demand-weighted average shortest path length."""
    f = n1 + n2
    path_bound = total_capacity / (aspl * f)
    if n1 == 0 or n2 == 0:
        return path_bound
    cut_bound = cut_capacity * (n1 + n2) / (2.0 * n1 * n2)
    return min(path_bound, cut_bound)


def cut_threshold(t_star: float, n1: int, n2: int) -> float:
    """C̄* = T*·2·n1·n2/(n1+n2): if the cross-cluster capacity C̄ is below
    this, throughput MUST be below the plateau value T* (paper Fig. 10)."""
    return t_star * 2.0 * n1 * n2 / (n1 + n2)
