"""Heterogeneous topology experiment drivers (paper §5, Figs. 3-7).

Every driver sweeps one (or two) design parameters of a two-class switch
network, builds the topology per the paper's recipe (servers first, then a
random graph over the remaining ports — biased across clusters if asked),
and measures max-concurrent-flow throughput over several seeded runs.

All sweeps are declarative ``engine.Sweep``s executed by
``engine.run_sweep``/``run_sweeps``: every (point × run) instance goes
through one ``solve_batch`` call, and the grid drivers (``combined_sweep``,
``line_speed_sweep``) route ALL of their member sweeps through a single
``run_sweeps`` call — one ``BatchPlan`` for the whole figure family on a
batching engine (``get_engine("dual")`` / ``"dual-pallas"``), instead of
one small batch per grid cell.  ``cross_cluster_sweep_item`` exposes the
(sweep, build_fn) building block so figure harnesses (e.g. Fig. 7's three
panels) can pool even more sweeps into one plan.  The ``engine`` argument
accepts a registry name or a ``ThroughputEngine`` instance; with a bracket
engine (``get_engine("certified")``) every returned ``SweepPoint`` also
carries ``lb_mean``/``gap_max`` — the certified lower-bound mean and the
worst relative bracket width across the point's runs.

The sweeps replay the paper's *recipes*; ``optimize_spec`` runs the
paper's *method* — a fleet search over the same pool via
``repro.design`` (one ``BatchPlan.execute`` per round).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import engine as engine_mod
from repro.core import graphs
from repro.core.engine import Sweep, SweepPoint, run_sweep, run_sweeps

__all__ = [
    "SweepPoint",
    "TwoClassSpec",
    "throughput",
    "build_two_class",
    "optimize_spec",
    "server_distribution_sweep",
    "power_law_beta_sweep",
    "cross_cluster_sweep",
    "cross_cluster_sweep_item",
    "combined_sweep",
    "line_speed_sweep",
    "line_speed_sweep_items",
]


@dataclasses.dataclass(frozen=True)
class TwoClassSpec:
    """A pool of two switch types (uniform line-speed unless h_* set)."""
    n_large: int
    k_large: int     # ports per large switch
    n_small: int
    k_small: int     # ports per small switch
    num_servers: int
    # optional high-line-speed ports on the LARGE switches (paper §5.2):
    h_links: int = 0        # number of high-speed ports per large switch
    h_speed: float = 1.0    # capacity of each high-speed port (units of base)

    @property
    def total_ports(self) -> int:
        return self.n_large * self.k_large + self.n_small * self.k_small

    @property
    def proportional_large_servers(self) -> int:
        """Expected servers on large switches if spread randomly over ports
        (the paper's x-axis normaliser; == proportional-to-port-count)."""
        return round(self.num_servers * self.n_large * self.k_large
                     / self.total_ports)


def throughput(cap, dem, engine="exact") -> float:
    """Deprecated shim: use ``get_engine(engine).solve(topo, dem)``."""
    return engine_mod.as_engine(engine).solve(cap, dem).throughput


def _spread_evenly(total: int, n: int) -> np.ndarray:
    """Split ``total`` across n switches as evenly as possible."""
    base = total // n
    out = np.full(n, base, dtype=np.int64)
    out[: total - base * n] += 1
    return out


def _even_degree_fixup(deg: np.ndarray) -> np.ndarray:
    """Leave one port unused on the highest-degree switch if the network
    degree sum is odd (the configuration model needs even stub count)."""
    if deg.sum() % 2 != 0:
        deg = deg.copy()
        deg[int(np.argmax(deg))] -= 1
    return deg


def build_two_class(spec: TwoClassSpec, servers_on_large: int,
                    cross_bias: float | None, seed: int,
                    server_nodes: bool = False) -> graphs.Topology:
    """Build the paper's two-class topology:

    * ``servers_on_large`` servers spread evenly over the large switches, the
      rest evenly over the small switches (footnote 4: within a class, even
      spread is optimal);
    * remaining (low-speed) ports wired as a random graph — unbiased if
      ``cross_bias`` is None, else with the cross-cluster edge count scaled
      by ``cross_bias`` relative to the unbiased expectation;
    * if the spec has high-speed ports, they form a random ``h_links``-regular
      graph among the large switches with capacity ``h_speed`` per link.
    """
    servers_on_large = int(np.clip(servers_on_large, 0, spec.num_servers))
    srv_l = _spread_evenly(servers_on_large, spec.n_large)
    srv_s = _spread_evenly(spec.num_servers - servers_on_large, spec.n_small)
    if np.any(srv_l >= spec.k_large + spec.h_links) or \
            np.any(srv_s >= spec.k_small):
        raise ValueError("server split leaves a switch without network ports")
    deg_l = spec.k_large - srv_l
    deg_s = spec.k_small - srv_s

    if cross_bias is None:
        deg = _even_degree_fixup(np.concatenate([deg_l, deg_s]))
        cap = graphs._random_graph_cap(deg, seed)
    else:
        # parity fixup per cluster happens inside via n_cross adjustment;
        # still guard each cluster's stub parity for the intra phase
        cap, _ = graphs._biased_two_cluster_cap(deg_l, deg_s, cross_bias,
                                                seed)

    if spec.h_links > 0 and spec.n_large > 1:
        h = min(spec.h_links, spec.n_large - 1)
        if spec.n_large * h % 2 != 0:
            h -= 1
        if h > 0:
            cap_h = graphs._random_regular_cap(spec.n_large, h, seed + 7,
                                               capacity=spec.h_speed)
            cap[: spec.n_large, : spec.n_large] += cap_h

    labels = np.concatenate([np.ones(spec.n_large, np.int64),
                             np.zeros(spec.n_small, np.int64)])
    topo = graphs.Topology(cap=cap, servers=np.concatenate([srv_l, srv_s]),
                           labels=labels)
    # server_nodes: the server-expanded view (one degree-1 leaf per server);
    # planning engines coarsen it back onto this switch graph by default
    return topo.with_server_nodes() if server_nodes else topo


def optimize_spec(spec: TwoClassSpec, *, engine=None,
                  moves: Sequence[str] = ("swap", "servers", "bias"),
                  rounds: int = 4, fleet: int = 12, elite: int = 4,
                  runs: int = 2, seed: int = 0, demand_fn=None):
    """Search the two-class pool for a high-throughput design instead of
    replaying the paper's recipe: a fleet of candidate wirings per round
    (degree-preserving edge swaps + server re-distribution + cross-bias
    perturbation over ``build_two_class``), each round ONE
    ``BatchPlan.execute``, final elites certified with the primal solver.
    Returns a ``repro.design.DesignResult``: ``best`` (certified-best
    candidate, never below the proportional/bias-1.0 ``reference``),
    ``elites``, per-round ``history``, plan/compile ``stats``, and a
    resumable ``state``.  The grid sweeps above answer "what does the
    recipe give"; this answers "what does the pool support"."""
    from repro.design import TwoClassSpace, optimize

    return optimize(TwoClassSpace(spec), demand_fn=demand_fn, engine=engine,
                    moves=moves, rounds=rounds, fleet=fleet, elite=elite,
                    runs=runs, seed=seed)


def server_distribution_sweep(spec: TwoClassSpec, xs: Sequence[float],
                              runs: int = 3, seed0: int = 0,
                              engine="exact") -> list[SweepPoint]:
    """Fig. 3: vary the share of servers on large switches.  x is normalised
    so x=1 ⇔ port-count-proportional distribution; interconnect unbiased."""
    prop = spec.proportional_large_servers

    def build(x: float, seed: int) -> graphs.Topology:
        return build_two_class(spec, round(x * prop), None, seed)

    return run_sweep(Sweep(xs=tuple(xs), runs=runs, seed0=seed0),
                     build, engine)


def power_law_beta_sweep(n: int, k_min: int, k_max: int, alpha: float,
                         num_servers: int, betas: Sequence[float],
                         runs: int = 3, seed0: int = 0,
                         engine="exact") -> list[SweepPoint]:
    """Fig. 4: power-law port counts; servers ∝ k_i^β; unbiased interconnect."""

    def build(beta: float, seed: int) -> graphs.Topology:
        ks = graphs.power_law_degrees(n, k_min, k_max, alpha, seed)
        srv = graphs.distribute_servers(ks, num_servers, beta)
        deg = _even_degree_fixup(ks - srv)
        # seed + 2: run_sweep draws the demand from seed + 1, and the graph
        # wiring must come from a distinct RNG stream
        return graphs.random_graph_from_degrees(deg, seed + 2, servers=srv)

    return run_sweep(Sweep(xs=tuple(betas), runs=runs, seed0=seed0),
                     build, engine)


def cross_cluster_sweep_item(spec: TwoClassSpec, biases: Sequence[float],
                             runs: int = 3, seed0: int = 0,
                             servers_on_large: int | None = None
                             ) -> tuple[Sweep, Callable]:
    """The (sweep, build_fn) pair of one cross-cluster bias sweep, for
    pooling several sweeps into one ``run_sweeps`` call (one ``BatchPlan``
    across a whole figure family)."""
    s_l = (spec.proportional_large_servers if servers_on_large is None
           else servers_on_large)

    def build(x: float, seed: int) -> graphs.Topology:
        return build_two_class(spec, s_l, x, seed)

    return Sweep(xs=tuple(biases), runs=runs, seed0=seed0), build


def cross_cluster_sweep(spec: TwoClassSpec, biases: Sequence[float],
                        runs: int = 3, seed0: int = 0,
                        engine="exact",
                        servers_on_large: int | None = None) -> list[SweepPoint]:
    """Fig. 5 (and 7 with h_links set): proportional servers, vary the
    cross-cluster edge count as a multiple of the unbiased expectation."""
    sweep, build = cross_cluster_sweep_item(spec, biases, runs, seed0,
                                            servers_on_large)
    return run_sweep(sweep, build, engine)


def combined_sweep(spec: TwoClassSpec,
                   server_splits: Sequence[tuple[int, int]],
                   biases: Sequence[float], runs: int = 3, seed0: int = 0,
                   engine="exact") -> dict[tuple[int, int], list[SweepPoint]]:
    """Fig. 6 / 7(a): grid over (per-large, per-small) server splits × bias.
    Each split is (servers per large switch, servers per small switch) and
    must sum to spec.num_servers.  The whole grid goes through ONE
    ``run_sweeps`` call — one ``BatchPlan`` on a batching engine."""
    items, keys = [], []
    for (per_l, per_s) in server_splits:
        tot = per_l * spec.n_large + per_s * spec.n_small
        if tot != spec.num_servers:
            raise ValueError(f"split {(per_l, per_s)} gives {tot} servers, "
                             f"spec has {spec.num_servers}")
        items.append(cross_cluster_sweep_item(
            spec, biases, runs, seed0,
            servers_on_large=per_l * spec.n_large))
        keys.append((per_l, per_s))
    return dict(zip(keys, run_sweeps(items, engine)))


def line_speed_sweep_items(spec: TwoClassSpec, biases: Sequence[float],
                           h_speeds: Sequence[float] | None = None,
                           h_counts: Sequence[int] | None = None,
                           runs: int = 3, seed0: int = 0
                           ) -> tuple[list[float | int],
                                      list[tuple[Sweep, Callable]]]:
    """(keys, items) of the Fig. 7(b)/(c) line-speed settings — one
    cross-cluster sweep per ``h_speed``/``h_links`` value — for pooling
    into a ``run_sweeps`` call (figure harnesses add their own panels)."""
    items: list[tuple[Sweep, Callable]] = []
    keys: list[float | int] = []
    for s in (h_speeds if h_speeds is not None else ()):
        sp = dataclasses.replace(spec, h_speed=float(s))
        items.append(cross_cluster_sweep_item(sp, biases, runs, seed0))
        keys.append(float(s))
    for hc in (h_counts if h_counts is not None else ()):
        sp = dataclasses.replace(spec, h_links=int(hc))
        items.append(cross_cluster_sweep_item(sp, biases, runs, seed0))
        keys.append(int(hc))
    return keys, items


def line_speed_sweep(spec: TwoClassSpec, biases: Sequence[float],
                     h_speeds: Sequence[float] | None = None,
                     h_counts: Sequence[int] | None = None,
                     runs: int = 3, seed0: int = 0,
                     engine="exact") -> dict[float | int, list[SweepPoint]]:
    """Fig. 7(b)/(c): vary the line-speed (or count) of the high-speed links
    on the large switches, sweeping cross-cluster bias for each setting.
    All settings pool into ONE ``run_sweeps`` call (one ``BatchPlan``)."""
    keys, items = line_speed_sweep_items(spec, biases, h_speeds, h_counts,
                                         runs, seed0)
    return dict(zip(keys, run_sweeps(items, engine)))
