from repro.checkpoint.checkpointing import (  # noqa: F401
    save_checkpoint, restore_checkpoint, latest_step, Checkpointer,
)
