"""Atomic, mesh-agnostic checkpoints with elastic re-shard on restore.

Fault-tolerance contract:

* **Atomicity** — state is serialised to ``step_XXXXXXXX.npz.tmp`` and
  os.replace'd into place; a crash mid-write never corrupts the latest
  complete checkpoint, and restart always resumes from the newest complete
  one (partial files are ignored and garbage-collected).
* **Mesh-agnostic** — arrays are saved in their full logical shape
  (device-gathered), so a job restarted on a *different* mesh (fewer pods,
  different DP/TP split — elastic scaling) restores by device_put'ing each
  array with the *new* sharding; nothing in the file depends on the old
  topology.
* **Complete state** — params, optimizer state, data cursor (an int — the
  pipeline is counter-based, see repro.data) and the RNG key all live in
  one pytree, so a restore is bitwise-resumable.
* **Multi-host** — only process 0 writes (jax.process_index() == 0); all
  hosts restore.  In this single-process container that is the identity.

Retention keeps the last ``keep`` checkpoints (the restart window) and
deletes older ones after a successful write, never before.
"""
from __future__ import annotations

import dataclasses
import os
import re
import tempfile

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "Checkpointer"]

_FILE_RE = re.compile(r"^step_(\d{8})\.npz$")


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(template, arrays: dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key} has shape {arr.shape}, "
                f"template wants {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, state) -> str:
    """Atomically write ``state`` (any pytree) for ``step``."""
    if jax.process_index() != 0:
        return ""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **_flatten(state))
        os.replace(tmp, final)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for n in os.listdir(ckpt_dir)
             if (m := _FILE_RE.match(n))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``template``.  ``shardings`` (optional
    pytree of NamedSharding, e.g. for a *new* mesh) re-shards every leaf —
    this is the elastic-rescale path."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    state = _unflatten(template, arrays)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            state, shardings)
    return step, state


@dataclasses.dataclass
class Checkpointer:
    """save-every-N with retention; wraps the functions above."""
    ckpt_dir: str
    every: int = 100
    keep: int = 3

    def maybe_save(self, step: int, state) -> bool:
        if step % self.every != 0:
            return False
        save_checkpoint(self.ckpt_dir, step, state)
        self._gc()
        return True

    def _gc(self) -> None:
        if jax.process_index() != 0 or not os.path.isdir(self.ckpt_dir):
            return
        entries = sorted(
            (int(m.group(1)), n) for n in os.listdir(self.ckpt_dir)
            if (m := _FILE_RE.match(n)))
        for _, name in entries[:-self.keep]:
            os.unlink(os.path.join(self.ckpt_dir, name))
        # sweep orphaned tmp files from crashed writes
        for n in os.listdir(self.ckpt_dir):
            if n.endswith(".tmp"):
                os.unlink(os.path.join(self.ckpt_dir, n))
