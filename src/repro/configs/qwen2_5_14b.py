"""qwen2.5-14b [dense]: GQA + QKV bias (Qwen2 family; hf:Qwen/Qwen2.5-14B)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=13824, vocab_size=152064,
    head_dim=128, qkv_bias=True, rope_theta=1e6)

SMOKE = ModelConfig(
    name="qwen2.5-14b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=160, vocab_size=256,
    head_dim=16, qkv_bias=True, dtype="float32")
