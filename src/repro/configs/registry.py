"""Architecture registry: --arch <id> -> ModelConfig (+ smoke variant)."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

__all__ = ["ARCH_IDS", "get_config", "get_smoke", "expert_parallel_ok"]

# assignment id -> module name under repro.configs
ARCH_IDS = {
    "qwen2.5-14b": "qwen2_5_14b",
    "minitron-4b": "minitron_4b",
    "granite-20b": "granite_20b",
    "mistral-large-123b": "mistral_large_123b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "rwkv6-7b": "rwkv6_7b",
    "musicgen-medium": "musicgen_medium",
}


def _module(arch: str):
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{ARCH_IDS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def expert_parallel_ok(cfg: ModelConfig, model_axis: int) -> bool:
    return cfg.num_experts > 0 and cfg.num_experts % model_axis == 0
