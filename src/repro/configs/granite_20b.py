"""granite-20b [dense]: llama-arch code model with MQA (arXiv:2405.04324)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense", num_layers=52, d_model=6144,
    num_heads=48, num_kv_heads=1, d_ff=24576, vocab_size=49152,
    head_dim=128)

SMOKE = ModelConfig(
    name="granite-20b-smoke", family="dense", num_layers=2, d_model=48,
    num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=256,
    head_dim=12, dtype="float32")
