"""qwen2-vl-7b [vlm]: M-RoPE + dynamic-resolution ViT frontend
(arXiv:2409.12191).  The ViT is a STUB per the assignment: input_specs
supplies precomputed patch embeddings (frontend_dim=1176 = 14x14 patch x 3ch
x 2 temporal); the backbone fuses them as a prefix."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    head_dim=128, qkv_bias=True, rope_theta=1e6,
    frontend="patch", frontend_dim=1176, frontend_len=256,
    mrope_sections=(16, 24, 24))

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
    qkv_bias=True, frontend="patch", frontend_dim=24, frontend_len=16,
    mrope_sections=(2, 3, 3), dtype="float32")
