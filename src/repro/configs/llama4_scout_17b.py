"""llama4-scout-17b-a16e [moe]: 16 experts top-1 (early-fusion multimodal in
the real model; the text backbone is what the pool assigns).  16 experts
divide the model axis -> true expert parallelism."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=8192, vocab_size=202048,
    head_dim=128, num_experts=16, experts_per_token=1, rope_theta=5e5)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=256, head_dim=16,
    num_experts=4, experts_per_token=1, moe_group=64, dtype="float32")
