"""mistral-large-123b [dense] (hf:mistralai/Mistral-Large-Instruct-2407)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense", num_layers=88, d_model=12288,
    num_heads=96, num_kv_heads=8, d_ff=28672, vocab_size=32768,
    head_dim=128, rope_theta=1e6)

SMOKE = ModelConfig(
    name="mistral-large-123b-smoke", family="dense", num_layers=3, d_model=96,
    num_heads=6, num_kv_heads=2, d_ff=224, vocab_size=256,
    head_dim=16, dtype="float32")
