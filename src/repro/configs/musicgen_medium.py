"""musicgen-medium [audio]: decoder-only LM over EnCodec tokens
(arXiv:2306.05284).  MHA (kv == heads).  The EnCodec tokenizer/frontend is a
STUB per the assignment: the LM consumes precomputed acoustic token ids
(vocab 2048); text conditioning is out of scope for the backbone."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio", num_layers=48, d_model=1536,
    num_heads=24, num_kv_heads=24, d_ff=6144, vocab_size=2048)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke", family="audio", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
    head_dim=16, dtype="float32")
