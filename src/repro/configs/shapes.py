"""Assigned input shapes (same four for every architecture)."""
from __future__ import annotations

import dataclasses

__all__ = ["ShapeConfig", "SHAPES", "applicable_shapes"]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int            # train/prefill: prompt length; decode: cache size
    global_batch: int
    kind: str               # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(family: str) -> list[str]:
    """long_500k needs sub-quadratic attention: it runs for the hybrid
    (local-window cache) and the SSM (O(1) state); pure full-attention archs
    skip it (DESIGN.md §Arch-applicability)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if family in ("hybrid", "ssm"):
        names.append("long_500k")
    return names
