from repro.configs.registry import (  # noqa: F401
    ARCH_IDS, get_config, get_smoke, expert_parallel_ok,
)
from repro.configs.shapes import (  # noqa: F401
    SHAPES, ShapeConfig, applicable_shapes,
)
