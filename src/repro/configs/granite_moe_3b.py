"""granite-moe-3b-a800m [moe]: 40 experts top-8, d_ff=512 per expert
(hf:ibm-granite family).  40 % 16 != 0 so expert weights run FSDP x TP
(every chip computes all experts for its tokens) instead of EP."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", num_layers=32, d_model=1536,
    num_heads=24, num_kv_heads=8, d_ff=512, vocab_size=49155,
    head_dim=64, num_experts=40, experts_per_token=8)

SMOKE = ModelConfig(
    name="granite-moe-3b-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=32, vocab_size=256, head_dim=16,
    num_experts=8, experts_per_token=2, moe_group=64, dtype="float32")
