"""rwkv6-7b [ssm]: Finch — attention-free, data-dependent decay
(arXiv:2404.05892).  64 heads of 64 channels; O(1) decode state."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", num_layers=32, d_model=4096,
    num_heads=0, num_kv_heads=0, d_ff=14336, vocab_size=65536,
    rwkv_head_dim=64)

SMOKE = ModelConfig(
    name="rwkv6-7b-smoke", family="ssm", num_layers=2, d_model=64,
    num_heads=0, num_kv_heads=0, d_ff=128, vocab_size=256,
    rwkv_head_dim=16, dtype="float32")
