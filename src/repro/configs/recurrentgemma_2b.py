"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 2:1 rec:attn
(Griffin, arXiv:2402.19427)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", num_layers=26, d_model=2560,
    num_heads=10, num_kv_heads=1, d_ff=7680, vocab_size=256000,
    head_dim=256, block_pattern=("rec", "rec", "attn"), local_window=2048,
    d_rnn=2560, conv_width=4)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid", num_layers=3, d_model=64,
    num_heads=2, num_kv_heads=1, d_ff=192, vocab_size=512, head_dim=32,
    block_pattern=("rec", "rec", "attn"), local_window=16, d_rnn=64,
    conv_width=4, dtype="float32")
