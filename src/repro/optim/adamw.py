"""AdamW + cosine schedule + global-norm clipping, pure JAX.

The optimizer state is a pytree shaped like the params (m, v in f32), so it
inherits the params' FSDP sharding via GSPMD propagation — no separate
sharding rules needed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "cosine_schedule"]


def cosine_schedule(peak_lr: float, warmup_steps: int,
                    total_steps: int, final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * (step + 1) / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    @staticmethod
    def global_norm(tree) -> jax.Array:
        leaves = jax.tree.leaves(tree)
        return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                            for g in leaves))

    def update(self, params, grads, state):
        gnorm = self.global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        step = state["step"] + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state["v"], grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, {"m": m, "v": v, "step": step}
