from repro.optim.adamw import AdamW, cosine_schedule  # noqa: F401
from repro.optim.compress import (  # noqa: F401
    int8_quantize, int8_dequantize, ef_compress_mean,
)
