"""Int8 error-feedback gradient compression for the cross-pod hop.

The paper's two-cluster analysis (§6.2) says throughput collapses once the
cross-cluster cut drops below C/(2<D>) — the training-fabric analogue is the
DCN link between pods, which is ~an order of magnitude thinner than in-pod
ICI.  We therefore compress exactly (and only) the cross-pod leg of the
gradient all-reduce:

  * the train step computes *per-pod* gradients by vmapping the microbatch
    grad over a leading pod dim that is sharded on the "pod" mesh axis
    (GSPMD then keeps that dim local — no cross-pod collective yet);
  * each pod quantises (grad + error_feedback) to int8 with a per-tensor
    scale; the mean over the pod dim is the only cross-pod collective and
    its operand is int8 — 4x fewer DCN bytes than f32, visible in the
    dry-run HLO;
  * the quantisation error is carried to the next step (error feedback),
    which keeps SGD/Adam convergence unbiased in practice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["int8_quantize", "int8_dequantize", "ef_compress_mean"]


def int8_quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantisation; returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_mean(grads_per_pod, error, npod: int, unshard_pod=None):
    """Compress + cross-pod mean with error feedback.

    grads_per_pod: pytree with leading dim [npod, ...] (sharded on "pod").
    error:         pytree like grads_per_pod (the EF buffer, bf16).
    unshard_pod:   callable resharding [npod, ...] from P("pod", ...) to
                   P(None, ...) — this forces the cross-pod collective to be
                   an all-gather whose operand is the *int8* q (4x fewer DCN
                   bytes than f32; verified in the dry-run HLO).
    Returns (mean_grads pytree without the pod dim, new_error).
    """
    def one(g, e):
        ge = g + e.astype(jnp.float32)
        # vmap over the pod dim so each pod has its own scale
        q, scale = jax.vmap(int8_quantize)(ge)
        # the barrier stops XLA's algebraic simplifier from cancelling the
        # s8->f32 round-trip (which would put f32 back on the DCN wire)
        q, scale = jax.lax.optimization_barrier((q, scale))
        # error feedback uses the pod-local dequantisation (before any comm)
        new_e = (ge - jax.vmap(int8_dequantize)(q, scale)).astype(jnp.bfloat16)
        if unshard_pod is not None:
            q = unshard_pod(q)          # <- the only cross-pod collective
            scale = unshard_pod(scale)
        mean = jnp.mean(jax.vmap(int8_dequantize)(q, scale), axis=0)
        return mean, new_e

    flat_g, tdef = jax.tree.flatten(grads_per_pod)
    flat_e = tdef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = tdef.unflatten([m for m, _ in out])
    new_err = tdef.unflatten([e for _, e in out])
    return means, new_err
