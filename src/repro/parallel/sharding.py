"""Sharding rules: DP(+FSDP) x TP(+SP) x EP, pod axis = outer DP.

The logical scheme (MaxText-style 2D + sequence parallelism):

* batch dims            -> ("pod", "data")           [DP; pod = outer DP]
* residual seq dim      -> "model"                   [SP between blocks]
* attention heads       -> "model"  (padded when the head count is uneven)
* ffn hidden / experts  -> "model"  (EP when num_experts % |model| == 0)
* parameters            -> one dim over "data" (FSDP), one over "model" (TP)
* kv-cache sequence     -> "model"  (flash-decoding: partial softmax/shard)

``make_shard_fn(mesh, rules)`` returns ``shard(x, name)`` used by the model
code; it resolves each named rule against the actual array shape:

* an axis that divides its dim is applied as-is;
* names in UNEVEN_OK keep the axis even when it does not divide (GSPMD pads
  internally — probed to work via with_sharding_constraint);
* otherwise the axis is dropped (e.g. the seq axis of a single decode token,
  or any dim on a single-device test mesh).

With mesh=None every constraint is a no-op, so model code is identical in
unit tests and in the 512-way dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "make_shard_fn", "param_specs", "batch_spec",
           "UNEVEN_OK"]

# activation names whose "model"-axis sharding may be uneven (GSPMD pads)
UNEVEN_OK = frozenset({"heads", "moe_experts"})

DP = ("pod", "data")     # flattened data-parallel axes (pod absent -> data)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """name -> PartitionSpec template (axis names or None per dim)."""
    rules: Mapping[str, tuple]

    @staticmethod
    def fsdp_only(dp_axes: tuple = DP) -> "ShardingRules":
        """Pure-FSDP profile: batch sharded over EVERY axis (data, model and
        pod all act as data parallelism), parameters 2D-sharded and gathered
        just-in-time per layer, no tensor parallelism.

        Rationale (hillclimb iteration 1): for small-d_model archs the
        Megatron TP+SP activation collectives (~6 x tokens x d_model bytes
        per layer) dwarf the per-chip compute; weight gathers
        (params_per_layer x 2B) are much smaller and overlappable.  Selected
        per-arch via ModelConfig.sharding_profile."""
        dp = tuple(a for a in dp_axes) + ("model",)
        base = dict(ShardingRules.default(dp_axes).rules)
        base.update({
            "act_btd":      (dp, None, None),
            "act_btd_full": (dp, None, None),
            "heads":        (dp, None, None, None),
            "attn_q_seq":   (dp, None, None, None, None),
            "attn_kv_rep":  (dp, None, None, None),
            "attn_acc_seq": (dp, None, None, None, None),
            "attn_out":     (dp, None, None, None),
            "ffn_hidden":   (dp, None, None),
            "logits":       (dp, None, None),
            "cache_kv":     (dp, "model", None, None),
            "rnn_state":    (dp, None),
            "moe_experts":  ("model", None, None, None),
            "moe_tokens":   (dp, None, None),
        })
        return ShardingRules(rules=base)

    @staticmethod
    def profile(name: str, dp_axes: tuple = DP) -> "ShardingRules":
        if name == "fsdp":
            return ShardingRules.fsdp_only(dp_axes)
        return ShardingRules.default(dp_axes)

    @staticmethod
    def default(dp_axes: tuple = DP) -> "ShardingRules":
        dp = dp_axes
        return ShardingRules(rules={
            # activations ----------------------------------------------------
            "act_btd":      (dp, "model", None),        # residual, SP on seq
            "act_btd_full": (dp, None, None),           # gathered residual
            "heads":        (dp, None, "model", None),  # [B, L, H, Dh]
            "attn_q_seq":   (dp, "model", None, None, None),  # [B,Lq,Hkv,g,D]
            "attn_kv_rep":  (dp, None, None, None),     # k/v replicated
            "attn_acc_seq": (dp, None, None, "model", None),  # [B,Hkv,g,Lq,D]
            "attn_out":     (dp, "model", None, None),  # [B, Lq, Hq, Dh]
            "ffn_hidden":   (dp, None, "model"),        # [B, L, F]
            "logits":       (dp, None, "model"),        # [B, L, V]
            "cache_kv":     (dp, "model", None, None),  # [B, Smax, Hkv, Dh]
            "rnn_state":    (dp, "model"),               # [B, D_rnn]
            "moe_experts":  ("model", None, None, None),  # [E, Gn, C, D] (EP)
            "moe_tokens":   (dp, None, None),             # [Gn, G, D]
            # parameters ------------------------------------------------------
            "p_emb":        (None, ("data", "model")),   # [V, D]  (lookup)
            "p_head":       ("data", "model"),           # [D, Vp] (logits)
            "p_norm":       (None,),
            "p_df":         ("data", "model"),           # [D, F]-like matrices
            "p_fd":         ("model", "data"),           # [F, D]-like matrices
            "p_bias":       ("model",),
            "p_router":     ("data", None),              # [D, E]
            "p_moe_dff":    (None, "data", "model"),     # [E, D, F]
            "p_moe_ffd":    (None, "model", "data"),     # [E, F, D]
            "p_moe_edff":   ("model", "data", None),     # [E, D, F] (EP)
            "p_moe_effd":   ("model", None, "data"),     # [E, F, D] (EP)
            "p_conv":       (None, "model"),             # [W, D_rnn]
            "p_vec":        ("model",),                  # [D_rnn]-like vectors
            "p_mu":         (None, "model"),             # [7, D] rwkv lerps
            # serving state ---------------------------------------------------
            "c_kv":         (None, dp, "model", None, None),  # [L,B,S,H,Dh]
            "c_rwkv_s":     (None, dp, "model", None, None),  # [L,B,H,n,n]
            "c_vec":        (None, dp, None),                 # [L, B, D]
            "c_ring_kv":    (dp, None, None, None),           # [B, W, Hkv, Dh]
            "c_rnn_h":      (dp, "model"),                    # [B, D_rnn]
            "c_conv":       (dp, None, "model"),              # [B, W-1, D_rnn]
            "c_scalar":     (),
        })


def _resolve(template: tuple, shape: tuple[int, ...], mesh: Mesh,
             uneven_ok: bool, leading: int = 0) -> P:
    """Turn a rule template into a PartitionSpec valid for ``shape``.

    ``leading`` extra unsharded dims are prepended (stacked-layer params)."""
    spec: list = [None] * leading
    tdims = template[-(len(shape) - leading):] if len(shape) > leading else ()
    for dim_size, axes in zip(shape[leading:], tdims):
        if axes is None:
            spec.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        ax_tuple = tuple(a for a in ax_tuple if a in mesh.axis_names)
        if not ax_tuple:
            spec.append(None)
            continue
        n = 1
        for a in ax_tuple:
            n *= mesh.shape[a]
        if dim_size % n == 0:
            spec.append(ax_tuple if len(ax_tuple) > 1 else ax_tuple[0])
        elif uneven_ok and dim_size >= n // 2:
            spec.append(ax_tuple if len(ax_tuple) > 1 else ax_tuple[0])
        else:
            spec.append(None)
    return P(*spec)


def make_shard_fn(mesh: Mesh | None,
                  rules: ShardingRules | None = None):
    """Returns shard(x, name) -> with_sharding_constraint'ed x."""
    if mesh is None or mesh.size == 1:
        return lambda x, name: x
    rules = rules or ShardingRules.default()

    def shard(x: jax.Array, name: str) -> jax.Array:
        template = rules.rules.get(name)
        if template is None:
            return x
        spec = _resolve(template, x.shape, mesh, uneven_ok=name in UNEVEN_OK)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


def param_specs(params_shapes, mesh: Mesh | None, name_of,
                rules: ShardingRules | None = None):
    """Pytree of NamedShardings for a pytree of ShapeDtypeStructs.

    ``name_of(path) -> (rule_name, n_leading_unsharded_dims)`` maps each
    param path to its rule.  Every spec here must shard evenly (checked) —
    params cross the jit boundary where GSPMD cannot pad.
    """
    if mesh is None:
        return jax.tree.map(lambda _: None, params_shapes)
    rules = rules or ShardingRules.default()

    def one(path, leaf):
        rule_name, leading = name_of(path)
        template = rules.rules[rule_name]
        spec = _resolve(template, leaf.shape, mesh, uneven_ok=False,
                        leading=leading)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def batch_spec(mesh: Mesh | None, ndim: int = 2) -> NamedSharding | None:
    """Sharding for [B, ...] host data: batch over (pod, data)."""
    if mesh is None:
        return None
    dp = tuple(a for a in DP if a in mesh.axis_names)
    return NamedSharding(mesh, P(dp, *([None] * (ndim - 1))))


# --------------------------------------------------------------------------
# parameter / state rule assignment by pytree path
# --------------------------------------------------------------------------

_PARAM_RULE_OF = {
    "emb": "p_emb", "head": "p_head", "final_norm": "p_norm",
    "ln1": "p_norm", "ln2": "p_norm", "ln_x": "p_vec",
    "wq": "p_df", "wk": "p_df", "wv": "p_df", "wg": "p_df", "wu": "p_df",
    "w_r": "p_df", "w_k": "p_df", "w_v": "p_df", "w_g": "p_df",
    "wk2": "p_df", "wr2": "p_df", "w_gate_in": "p_df", "w_rnn_in": "p_df",
    "w_a": "p_df", "w_x": "p_df", "decay_a": "p_df", "w_patch": "p_df",
    "wo": "p_fd", "wd": "p_fd", "wv2": "p_fd", "w_o": "p_fd",
    "decay_b": "p_fd", "w_out": "p_fd",
    "bq": "p_bias", "bk": "p_bias", "bv": "p_bias",
    "conv_b": "p_vec", "b_a": "p_vec", "b_x": "p_vec", "lam": "p_vec",
    "decay_base": "p_vec", "bonus": "p_vec",
    "conv_w": "p_conv", "mu": "p_mu", "router": "p_router",
}

_CACHE_RULE_OF = {
    "k": "c_kv", "v": "c_kv", "s": "c_rwkv_s",
    "shift1": "c_vec", "shift2": "c_vec", "pos": "c_scalar",
    "h": "c_rnn_h", "conv": "c_conv",
    "step": "c_scalar", "loss": "c_scalar", "aux_loss": "c_scalar",
    "grad_norm": "c_scalar",
}


def _path_keys(path) -> list[str]:
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(str(k.key))
        elif hasattr(k, "idx"):
            keys.append(int(k.idx))
    return keys


def make_param_rule(expert_parallel: bool = False):
    """name_of(path) for param_specs.  ``expert_parallel`` switches the MoE
    expert-weight layout (EP needs num_experts % |model| == 0)."""
    moe = {
        "we_gate": "p_moe_edff" if expert_parallel else "p_moe_dff",
        "we_up": "p_moe_edff" if expert_parallel else "p_moe_dff",
        "we_down": "p_moe_effd" if expert_parallel else "p_moe_ffd",
    }

    def name_of(path):
        keys = _path_keys(path)
        # stacked-on-L params live under a dict "blocks" with NO list index;
        # per-layer list params (the hybrid) have an integer in the path.
        stacked = ("blocks" in keys) and not any(
            isinstance(k, int) for k in keys)
        leading = 1 if stacked else 0
        last = next(k for k in reversed(keys) if isinstance(k, str))
        rule = moe.get(last) or _PARAM_RULE_OF.get(last)
        if rule is None:
            raise KeyError(f"no sharding rule for param path {keys}")
        return rule, leading

    return name_of


def cache_rule(path):
    """name_of(path) for decode-cache / metric trees.  Stacked-on-L cache
    leaves (dict layout) get leading=1; the hybrid's per-layer list entries
    get leading=0 (rules named c_ring_kv / c_rnn_h / c_conv)."""
    keys = _path_keys(path)
    last = next(k for k in reversed(keys) if isinstance(k, str))
    per_layer_list = any(isinstance(k, int) for k in keys)
    if per_layer_list:
        rule = {"k": "c_ring_kv", "v": "c_ring_kv", "h": "c_rnn_h",
                "conv": "c_conv"}.get(last, _CACHE_RULE_OF.get(last))
        return rule, 0
    rule = _CACHE_RULE_OF.get(last)
    if rule is None:
        raise KeyError(f"no cache rule for path {keys}")
    return rule, 0


def state_specs(tree_shapes, mesh: Mesh | None, kind: str = "param",
                expert_parallel: bool = False,
                rules: ShardingRules | None = None):
    """NamedShardings for params ("param"), optimizer state ("opt": params
    rules applied under m/v + replicated scalars + pod-leading ef_error), or
    decode caches ("cache")."""
    if mesh is None:
        return jax.tree.map(lambda _: None, tree_shapes)
    rules = rules or ShardingRules.default()
    prule = make_param_rule(expert_parallel)

    def one(path, leaf):
        keys = _path_keys(path)
        if kind == "cache":
            rule, leading = cache_rule(path)
        elif keys and keys[0] == "ef_error":
            rule, leading = prule(path[1:])
            spec = _resolve(rules.rules[rule], leaf.shape[1:], mesh,
                            uneven_ok=False, leading=leading)
            pod = "pod" if "pod" in mesh.axis_names else None
            return NamedSharding(mesh, P(pod, *spec))
        elif keys and keys[0] in ("m", "v"):
            rule, leading = prule(path[1:])
        elif keys and keys[0] == "step":
            return NamedSharding(mesh, P())
        else:
            rule, leading = prule(path)
        spec = _resolve(rules.rules[rule], leaf.shape, mesh,
                        uneven_ok=False, leading=leading)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, tree_shapes)
