from repro.parallel.sharding import (  # noqa: F401
    ShardingRules, make_shard_fn, param_specs, batch_spec,
)
