"""Size-bucketed padded batching + early-stopping dual engine (PR 2).

Covers: bucket-size selection, padded-vs-unpadded equivalence on mixed-size
batches, convergence-based early stopping (single and batched), per-instance
batch meta, and the interpret-mode auto-detection plumbing.
"""
import numpy as np
import pytest

from repro.core import graphs, mcf, traffic
from repro.core.engine import DualEngine, bucket_size
from repro.kernels import ops


def _instance(n, seed, r=4):
    topo = graphs.random_regular_graph(n, r, seed, servers=3)
    dem = traffic.make("permutation", topo.servers, seed + 1)
    return topo, dem


# ---------------------------------------------------------------------------
# bucket sizing
# ---------------------------------------------------------------------------

def test_bucket_size_modes():
    assert [bucket_size(n, "pow2") for n in (5, 8, 9, 40, 64, 65)] == \
        [8, 8, 16, 64, 64, 128]
    assert bucket_size(40, "mult128") == 128
    assert bucket_size(129, "mult128") == 256
    assert bucket_size(40, 32) == 64
    assert bucket_size(40, None) == 40
    assert bucket_size(40, "none") == 40
    with pytest.raises(ValueError, match="bucket mode"):
        bucket_size(40, "fib")
    with pytest.raises(ValueError, match="bucket mode"):
        DualEngine(bucket="fib")   # engine fails fast at construction


# ---------------------------------------------------------------------------
# padded batching == per-instance solves
# ---------------------------------------------------------------------------

def test_padded_batch_matches_per_instance_solve_dual():
    insts = [_instance(n, s) for s, n in enumerate([12, 14, 16, 20, 24])]
    eng = DualEngine(iters=300, bucket="pow2")
    out = eng.solve_batch([t for t, _ in insts], [d for _, d in insts])
    buckets = {r.meta["bucket"] for r in out}
    assert buckets == {16, 32}, "12/14/16 -> 16; 20/24 -> 32"
    for (topo, dem), got in zip(insts, out):
        ref = mcf.solve_dual(topo, dem, iters=300)
        assert got.throughput == pytest.approx(ref.throughput_ub, rel=1e-3)
        assert got.meta["nodes"] == topo.n


def test_padded_solve_dual_batch_masks_padding():
    topo, dem = _instance(16, 0)
    ref = mcf.solve_dual(topo, dem, iters=300)
    capp = np.zeros((1, 32, 32), np.float32)
    demp = np.zeros((1, 32, 32), np.float32)
    capp[0, :16, :16] = topo.cap
    demp[0, :16, :16] = dem
    res = mcf.solve_dual_batch(capp, demp, n_valid=np.array([16]), iters=300)
    assert res.throughput_ub[0] == pytest.approx(ref.throughput_ub, rel=1e-3)
    assert res.iterations[0] == 300
    assert np.isfinite(res.final_ratio[0])


# ---------------------------------------------------------------------------
# early stopping
# ---------------------------------------------------------------------------

def test_early_stop_fewer_iters_same_bound():
    topo, dem = _instance(16, 3)
    full = mcf.solve_dual(topo, dem, iters=2000)
    assert full.iterations == 2000
    tol = 1e-4
    early = mcf.solve_dual(topo, dem, iters=2000, tol=tol)
    assert early.iterations < 2000, "tolerance reached => early exit"
    assert early.iterations % 25 == 0, "stops on a check boundary"
    # certified bound unchanged within a few windows' worth of tolerance
    # (the window depends on how the SP-DAG adjoint splits ties, so the
    # margin is loose; see repro.core.apsp)
    assert early.throughput_ub == pytest.approx(full.throughput_ub, rel=0.03)
    assert early.throughput_ub >= full.throughput_ub - 1e-6, \
        "early bound is still an upper bound on the converged one"


def test_batch_early_stop_is_per_instance():
    insts = [_instance(n, s) for s, n in enumerate([12, 16, 16, 20])]
    eng = DualEngine(iters=1500, tol=1e-4, bucket="pow2")
    out = eng.solve_batch([t for t, _ in insts], [d for _, d in insts])
    its = [r.meta["iterations"] for r in out]
    assert all(i < 1500 for i in its)
    assert len(set(its)) > 1, "lanes converge at different iterations"
    for (topo, dem), got in zip(insts, out):
        # same tolerance per-instance solve: padding must not change when or
        # where a lane stops (modulo float noise)
        same = mcf.solve_dual(topo, dem, iters=1500, tol=1e-4)
        assert got.throughput == pytest.approx(same.throughput_ub, rel=5e-3)
        # still a certified bound, within a couple percent of the full run
        full = mcf.solve_dual(topo, dem, iters=1500)
        assert got.throughput >= full.throughput_ub - 1e-6
        assert got.throughput == pytest.approx(full.throughput_ub, rel=0.025)


def test_tol_zero_never_stops_early():
    topo, dem = _instance(12, 7)
    res = mcf.solve_dual(topo, dem, iters=120, tol=0.0)
    assert res.iterations == 120


# ---------------------------------------------------------------------------
# batch meta (satellite: solve_batch used to report the cap + drop ratio)
# ---------------------------------------------------------------------------

def test_solve_batch_meta_matches_solver_outputs():
    insts = [_instance(n, s) for s, n in enumerate([12, 16])]
    eng = DualEngine(iters=200, bucket="pow2")
    out = eng.solve_batch([t for t, _ in insts], [d for _, d in insts])
    for (topo, dem), got in zip(insts, out):
        assert set(got.meta) == {"iterations", "final_ratio", "batch_size",
                                 "bucket", "padded_n", "nodes", "chunk",
                                 "chunks", "devices", "plan"}
        assert got.meta["iterations"] == 200
        assert np.isfinite(got.meta["final_ratio"])
        assert got.meta["plan"]["instances"] == 2
        assert got.meta["chunk"] < got.meta["chunks"]
        single = eng.solve(topo, dem)
        assert got.meta["final_ratio"] == pytest.approx(
            single.meta["final_ratio"], rel=1e-3)


def test_empty_batch_returns_empty():
    # regression: np.stack([]) used to blow up with an opaque error
    empty = mcf.solve_dual_batch([], [])
    assert isinstance(empty, mcf.DualBatchResult)
    assert len(empty) == 0 and list(empty) == []
    assert empty.iterations.shape == (0,)
    assert DualEngine(iters=50).solve_batch([], []) == []


def test_batch_length_mismatch_raises():
    topo, dem = _instance(12, 0)
    with pytest.raises(ValueError, match="equal length"):
        mcf.solve_dual_batch([topo.cap], [])
    with pytest.raises(ValueError, match="equal length"):
        mcf.solve_dual_batch([], [dem])


def test_solve_dual_batch_result_is_sequence_of_bounds():
    caps = np.stack([graphs.random_regular_graph(12, 4, s).cap
                     for s in range(3)])
    dems = np.stack([traffic.make("permutation", np.full(12, 2), s)
                     for s in range(3)])
    res = mcf.solve_dual_batch(caps, dems, iters=100)
    assert len(res) == 3
    assert list(res) == [res[i] for i in range(3)]
    assert res.iterations.shape == (3,)


# ---------------------------------------------------------------------------
# interpret-mode plumbing
# ---------------------------------------------------------------------------

def test_resolve_interpret():
    import jax
    assert ops.resolve_interpret(True) is True
    assert ops.resolve_interpret(False) is False
    auto = ops.resolve_interpret(None)
    assert auto == (jax.default_backend() != "tpu")


def test_dual_pallas_interpret_threads_through_engine():
    # explicit interpret=True must work on any backend; use_pallas on a
    # small instance exercises the ref fallback inside ops.minplus_matmul
    topo, dem = _instance(16, 1)
    eng = DualEngine(use_pallas=True, interpret=True, iters=150)
    plain = DualEngine(iters=150)
    a = eng.solve(topo, dem)
    b = plain.solve(topo, dem)
    assert a.throughput == pytest.approx(b.throughput, rel=1e-3)
    out = eng.solve_batch([topo], [dem])
    assert out[0].throughput == pytest.approx(a.throughput, rel=1e-3)
