"""VL2 rewiring (paper §7) + fabric collective-bandwidth model."""
import numpy as np
import pytest

from repro.core import fabric, lp, traffic, vl2


SPEC = vl2.VL2Spec(d_a=6, d_i=6, servers_per_tor=5)


def test_vl2_structure():
    topo = vl2.vl2_topology(SPEC)
    topo.validate()
    n_tor, na, nc = SPEC.n_tor_full, SPEC.n_agg, SPEC.n_core
    assert topo.n == n_tor + na + nc
    # ToRs: exactly 2 x 10G uplinks
    assert np.all(topo.cap[:n_tor].sum(1) == 2 * vl2.FABRIC)
    # full bipartite agg-core
    agg_core = topo.cap[n_tor:n_tor + na, n_tor + na:]
    assert np.all(agg_core == vl2.FABRIC)


def test_vl2_single_agg_doubles_uplink():
    # na == 1 (d_i = 1): round-robin has nowhere else to go, so BOTH ToR
    # uplinks land on the single agg as one doubled-capacity link (pins the
    # intended behaviour after removing the dead a2-reassignment branch)
    spec = vl2.VL2Spec(d_a=4, d_i=1, servers_per_tor=5)
    assert spec.n_agg == 1
    topo = vl2.vl2_topology(spec)
    n_tor, agg0 = spec.n_tor_full, spec.n_tor_full
    assert np.all(topo.cap[:n_tor, agg0] == 2 * vl2.FABRIC)
    assert np.all(topo.cap[:n_tor].sum(1) == 2 * vl2.FABRIC)
    topo.validate()


def test_vl2_supports_full_throughput_by_design():
    topo = vl2.vl2_topology(SPEC)
    dem = traffic.random_permutation(topo.servers, 0)
    th = lp.max_concurrent_flow(topo.cap, dem, want_flows=False).throughput
    assert th >= 1.0 - 1e-6


def test_rewired_vl2_uses_same_equipment():
    topo = vl2.rewired_vl2_topology(SPEC, SPEC.n_tor_full, seed=0)
    topo.validate()
    n_tor = SPEC.n_tor_full
    # same ToR uplink count and same total fabric port count (+- parity fixup)
    assert np.all(topo.cap[:n_tor].sum(1) == 2 * vl2.FABRIC)
    ports_used = topo.cap.sum() / vl2.FABRIC   # stub count (both dirs)
    max_ports = 2 * n_tor * 2 + 0  # uplinks counted twice
    total_fabric_ports = SPEC.n_agg * SPEC.d_a + SPEC.n_core * SPEC.d_i
    assert ports_used <= (2 * n_tor + total_fabric_ports) + 1


def test_rewired_supports_at_least_as_many_tors():
    # paper ratio: 20 x 1G servers vs 2 x 10G uplinks (exactly balanced)
    spec20 = vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=20)
    base = spec20.n_tor_full
    best = vl2.max_tors_at_full_throughput(
        spec20, vl2.rewired_vl2_topology, lo=base, hi=base + 4, runs=2,
        seed0=0)
    assert best >= base, "rewiring must not lose capacity (paper Fig. 11)"


def test_binary_search_raises_on_bad_lower():
    def broken(spec, n_tor, seed):
        t = vl2.rewired_vl2_topology(spec, n_tor, seed)
        cap = t.cap * 1e-3    # starved network
        return type(t)(cap=cap, servers=t.servers, labels=t.labels)
    with pytest.raises(ValueError):
        vl2.max_tors_at_full_throughput(SPEC, broken, lo=4, hi=8, runs=1)


# ---------------------------------------------------------------------------
# fabric model
# ---------------------------------------------------------------------------

def test_fabric_design_valid():
    d = fabric.design_fabric([24] * 4 + [8] * 8, num_pods=12, seed=0)
    d.topology.validate()
    assert len(d.pod_switch) == 12
    assert d.topology.servers.sum() == 12


def test_fabric_paper_rule_beats_tor_packing():
    cmp = fabric.compare_with_traditional([24] * 4 + [8] * 8, num_pods=12,
                                          runs=2)
    assert cmp["paper"] > cmp["traditional"]


def test_collective_patterns():
    d = fabric.design_fabric([16] * 6, num_pods=8, seed=1)
    ring = fabric.collective_bandwidth(d, "ring")
    a2a = fabric.collective_bandwidth(d, "alltoall")
    ag = fabric.collective_bandwidth(d, "allgather")
    assert ring > 0 and a2a > 0 and ag > 0
    assert ag <= a2a + 1e-6, "allgather moves (P-1)x the volume"
