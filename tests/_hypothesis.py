"""Optional-hypothesis shim for the test suite.

``from tests._hypothesis import given, settings, st`` works whether or not
hypothesis is installed.  Without it, ``@given(...)`` marks the test as
skipped (and the strategy expressions evaluate to inert placeholders), so
the rest of the module's tests still run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kw):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kw):
        def deco(fn):
            return fn
        return deco

    class _Inert:
        """Placeholder strategy: constructors and chained combinators
        (``.filter``, ``.map``, ``.flatmap``, ...) are evaluated at
        decoration time, so every attribute access and call must absorb
        into another placeholder; the test never actually runs."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _Inert()
