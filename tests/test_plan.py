"""BatchPlan execution core: bucketing, chunking under a lane budget, and
multi-device sharded dual solves.

The multi-device tests need several XLA devices; CI runs this module as a
dedicated matrix entry with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m pytest tests/test_plan.py -q

In the plain tier-1 run (one CPU device) those tests skip and the
single-device planning/chunking tests still execute.
"""
import jax
import numpy as np
import pytest

from repro.core import graphs, mcf, traffic
from repro.core.engine import DualEngine
from repro.core.plan import BatchPlan, bucket_size, device_count

NDEV = len(jax.local_devices())
needs_8_devices = pytest.mark.skipif(
    NDEV < 8, reason="run with XLA_FLAGS=--xla_force_host_platform_"
                     "device_count=8 to exercise the sharded path")


def _instances(ns, deg=4, servers=3):
    topos, dems = [], []
    for s, n in enumerate(ns):
        t = graphs.random_regular_graph(n, deg, seed=s, servers=servers)
        topos.append(t)
        dems.append(traffic.make("permutation", t.servers, seed=s + 1))
    return topos, dems


def _bounds(results):
    return np.array([r.throughput for r in results])


# ---------------------------------------------------------------------------
# plan structure (device-count independent)
# ---------------------------------------------------------------------------

def test_plan_buckets_and_padding():
    topos, dems = _instances([12, 14, 16, 20, 24, 33])
    plan = BatchPlan.build(topos, dems, bucket="pow2", devices=1)
    assert plan.stats.instances == 6
    assert plan.stats.buckets == 3          # 16 / 32 / 64
    assert plan.stats.chunks == 3           # no lane budget: one per bucket
    assert plan.stats.lanes_padded == 0     # 1 device: no batch padding
    # members pad to the largest member, not the bucket ceiling
    by_bucket = {c.bucket: c for c in plan.chunks}
    assert by_bucket[16].padded_n == 16
    assert by_bucket[32].padded_n == 24
    assert by_bucket[64].padded_n == 33
    assert set(plan.stats.compile_keys) == {(16, 3), (24, 2), (33, 1)}


def test_plan_chunking_under_lane_budget():
    topos, dems = _instances([16] * 7)
    plan = BatchPlan.build(topos, dems, max_lanes=3, devices=1)
    assert [len(c.indices) for c in plan.chunks] == [3, 3, 1]
    # trailing chunk padded to the shared shape: ONE compile key
    assert all(c.lanes == 3 for c in plan.chunks)
    assert plan.stats.compile_keys == ((16, 3),)
    assert plan.stats.lanes_padded == 2
    # padded lanes replicate a real instance, never a zero instance
    capp, _, n_valid = plan._pack(plan.chunks[-1])
    assert np.array_equal(capp[1], capp[0]) and np.array_equal(capp[2],
                                                               capp[0])
    assert np.all(n_valid == 16)


def test_plan_chunked_results_match_unchunked():
    topos, dems = _instances([12, 14, 16, 20, 24, 33, 40, 40])
    whole = DualEngine(iters=150, devices=1)
    chunked = DualEngine(iters=150, max_lanes=2, devices=1)
    a = _bounds(whole.solve_batch(topos, dems))
    b = _bounds(chunked.solve_batch(topos, dems))
    assert np.array_equal(a, b), "chunking must not change any bound"
    assert chunked.last_plan.chunks > whole.last_plan.chunks


def test_plan_empty():
    plan = BatchPlan.build([], [], devices=1)
    assert plan.chunks == [] and plan.execute(iters=10) == []


def test_plan_rejects_bad_knobs():
    topos, dems = _instances([12])
    with pytest.raises(ValueError, match="max_lanes"):
        BatchPlan.build(topos, dems, max_lanes=0)
    with pytest.raises(ValueError, match="devices"):
        BatchPlan.build(topos, dems, devices=NDEV + 1)
    with pytest.raises(ValueError, match="equal length"):
        BatchPlan.build(topos, [])
    assert device_count(None) == NDEV


def test_engine_meta_reports_plan_placement():
    topos, dems = _instances([12, 16, 16])
    eng = DualEngine(iters=100, max_lanes=2, devices=1)
    out = eng.solve_batch(topos, dems)
    assert [r.meta["chunk"] for r in out] == [0, 0, 1]
    assert all(r.meta["devices"] == 1 for r in out)
    assert out[0].meta["plan"] == eng.last_plan.as_dict()


# ---------------------------------------------------------------------------
# sharded path (8 virtual CPU devices in the CI matrix entry)
# ---------------------------------------------------------------------------

@needs_8_devices
def test_sharded_bounds_bit_identical_to_single_device():
    # 10 mixed-size instances: uneven against 8 devices in every bucket
    topos, dems = _instances([12, 14, 16, 16, 20, 20, 24, 24, 33, 40])
    one = DualEngine(iters=150, devices=1)
    many = DualEngine(iters=150, devices=8)
    a = _bounds(one.solve_batch(topos, dems))
    b = _bounds(many.solve_batch(topos, dems))
    assert np.array_equal(a, b), \
        "batch-axis sharding must not change any bound bit"
    assert many.last_plan.devices == 8
    # every chunk's lane count is a device multiple; the surplus lanes are
    # replicated real instances
    assert all(c.lanes % 8 == 0 for c in
               many.plan(topos, dems).chunks)
    assert many.last_plan.lanes_padded > 0


@needs_8_devices
def test_sharded_uneven_batch_to_device_split():
    # 5 equal-size instances over 8 devices: single chunk padded 5 -> 8
    topos, dems = _instances([16] * 5)
    eng = DualEngine(iters=150, devices=8)
    plan = eng.plan(topos, dems)
    assert [c.lanes for c in plan.chunks] == [8]
    assert plan.stats.lanes_padded == 3
    got = _bounds(eng.solve_batch(topos, dems))
    ref = _bounds(DualEngine(iters=150, devices=1).solve_batch(topos, dems))
    assert np.array_equal(got, ref)


@needs_8_devices
def test_sharded_chunking_under_tiny_lane_budget():
    # budget below the device count is bumped to one lane per device;
    # a non-multiple budget floors to the device multiple
    topos, dems = _instances([16] * 20)
    eng = DualEngine(iters=120, tol=1e-3, devices=8, max_lanes=12)
    plan = eng.plan(topos, dems)
    assert all(c.lanes == 8 for c in plan.chunks)       # 12 -> floor -> 8
    assert [len(c.indices) for c in plan.chunks] == [8, 8, 4]
    got = _bounds(eng.solve_batch(topos, dems))
    ref = _bounds(DualEngine(iters=120, tol=1e-3, devices=1,
                             bucket="pow2").solve_batch(topos, dems))
    # early stopping is per-chunk: a chunk may retire at a different check
    # window than the whole-bucket batch, so compare loosely
    assert got == pytest.approx(ref, rel=5e-3)


@needs_8_devices
def test_sharded_primal_bounds_bit_identical_to_single_device():
    # the primal FW solver rides the same sharded plan machinery
    from repro.core.engine import PrimalEngine
    topos, dems = _instances([12, 14, 16, 16, 20])
    a = _bounds(PrimalEngine(iters=120, devices=1).solve_batch(topos, dems))
    b = _bounds(PrimalEngine(iters=120, devices=8).solve_batch(topos, dems))
    assert np.array_equal(a, b), \
        "batch-axis sharding must not change any primal bound bit"


@needs_8_devices
def test_sharded_empty_and_single_instance():
    assert DualEngine(devices=8).solve_batch([], []) == []
    topos, dems = _instances([16])
    got = DualEngine(iters=150, devices=8).solve_batch(topos, dems)
    ref = mcf.solve_dual(topos[0], dems[0], iters=150)
    assert got[0].throughput == pytest.approx(ref.throughput_ub, rel=1e-4)


def test_bucket_size_reexport_consistency():
    from repro.core import engine as engine_mod
    assert engine_mod.bucket_size is bucket_size
