"""AST-based seed audit for the test suite.

An unseeded generator (``np.random.default_rng()`` / ``RandomState()``
with no arguments, or the legacy seedless ``np.random.seed()``) makes a
test's inputs irreproducible: a failure seen in CI cannot be replayed
locally.  ``tests/conftest.py`` runs :func:`unseeded_rng_calls` over
every collected test file after collection and fails the session if any
construction slipped in.  Kept in its own helper module (like
``tests/_hypothesis.py``) so the check itself is unit-testable
(``tests/test_routing.py::test_seedcheck_*``).
"""
from __future__ import annotations

import ast

# call names whose zero-argument form constructs unseeded randomness
_BAD_ZERO_ARG = {"default_rng", "RandomState", "seed"}


def _call_name(node: ast.Call) -> str | None:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def unseeded_rng_calls(source: str, filename: str = "<test>") -> list[str]:
    """Scan python ``source`` for unseeded rng constructions; returns
    ``"<filename>:<line>: <message>"`` strings (empty = clean).  Only
    zero-argument forms are flagged — ``default_rng(0)``,
    ``default_rng(seed)`` and friends always pass."""
    tree = ast.parse(source, filename=filename)
    bad = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name in _BAD_ZERO_ARG and not node.args and not node.keywords:
            bad.append(f"{filename}:{node.lineno}: unseeded "
                       f"{name}() — pass an explicit seed so the test "
                       "is reproducible")
    return bad
