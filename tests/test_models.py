"""Model substrate: forward/prefill/decode consistency for every family,
loss masking, M-RoPE reduction, MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers, model as model_lib, moe as moe_lib
from repro.models.config import ModelConfig


DENSE = ModelConfig(name="t-dense", family="dense", num_layers=3, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                    head_dim=16, qkv_bias=True, dtype="float32")
MOE = ModelConfig(name="t-moe", family="moe", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=50,
                  head_dim=8, num_experts=4, experts_per_token=2,
                  moe_group=8, moe_capacity_factor=4.0, dtype="float32")
HYBRID = ModelConfig(name="t-hyb", family="hybrid", num_layers=6, d_model=48,
                     num_heads=4, num_kv_heads=1, d_ff=96, vocab_size=61,
                     head_dim=12, block_pattern=("rec", "rec", "attn"),
                     local_window=8, d_rnn=48, dtype="float32")
SSM = ModelConfig(name="t-ssm", family="ssm", num_layers=3, d_model=32,
                  num_heads=0, num_kv_heads=0, d_ff=64, vocab_size=53,
                  rwkv_head_dim=8, dtype="float32")
VLM = ModelConfig(name="t-vlm", family="vlm", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                  head_dim=8, qkv_bias=True, frontend="patch",
                  frontend_dim=12, frontend_len=4,
                  mrope_sections=(1, 1, 2), dtype="float32")


@pytest.mark.parametrize(
    "cfg", [DENSE, MOE,
            pytest.param(HYBRID, marks=pytest.mark.slow), SSM],
    ids=lambda c: c.family)
def test_decode_matches_forward(cfg):
    model = model_lib.get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    b, s = 2, 19
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    last, cache = model.prefill(params, {"tokens": toks[:, :s - 3]},
                                max_len=s + 2)
    ref = model.forward(params, {"tokens": toks[:, :s - 3]})[0][:, -1]
    np.testing.assert_allclose(last, ref, atol=1e-3)
    for t in range(s - 3, s):
        last, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        full = model.forward(params, {"tokens": toks[:, :t + 1]})[0][:, -1]
        np.testing.assert_allclose(last, full, atol=2e-3)


def test_logits_shape_uses_padded_vocab():
    model = model_lib.get_model(DENSE)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 8), jnp.int32)
    logits, _, _ = model.forward(params, {"tokens": toks})
    assert logits.shape[-1] == DENSE.padded_vocab == 256


def test_cross_entropy_masks_padded_vocab_and_labels():
    logits = jnp.zeros((1, 4, 256))
    labels = jnp.array([[1, 2, -1, 3]])
    loss, n = model_lib.cross_entropy(DENSE, logits, labels)
    assert n == 3
    # uniform over the REAL vocab only -> loss = log(97)
    np.testing.assert_allclose(loss, np.log(97), rtol=1e-5)


def test_vlm_patch_fusion_and_mrope():
    model = model_lib.get_model(VLM)
    params = model.init_params(jax.random.PRNGKey(0))
    b, p, s_text = 2, 4, 8
    s = p + s_text
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s_text), 0, 64),
        "patch_embeds": jax.random.normal(jax.random.PRNGKey(2), (b, p, 12)),
        "positions": jnp.broadcast_to(jnp.arange(s)[None, None], (b, 3, s)),
    }
    logits, _, _ = model.forward(params, batch)
    assert logits.shape == (b, s, VLM.padded_vocab)
    assert not bool(jnp.isnan(logits).any())


def test_mrope_equals_rope_when_components_equal():
    pos = jnp.arange(16)
    sin1, cos1 = layers.rope(pos, 8)
    p3 = jnp.broadcast_to(pos[None, None], (1, 3, 16))
    sin2, cos2 = layers.m_rope(p3, 8, (1, 1, 2))
    np.testing.assert_allclose(sin1, sin2[0], atol=1e-6)
    np.testing.assert_allclose(cos1, cos2[0], atol=1e-6)


def test_moe_dispatch_respects_capacity_and_gates():
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4)), -1)
    dispatch, combine = moe_lib._top_k_dispatch(probs, k=2, capacity=3)
    # every slot holds at most one token
    assert float(dispatch.sum(axis=1).max()) <= 1.0 + 1e-6
    # each token dispatched at most k times
    assert float(dispatch.sum(axis=(2, 3)).max()) <= 2 + 1e-6
    # combine weights match gate probs where dispatched
    sel = dispatch > 0
    gates = jnp.where(sel, combine, 0.0).sum(axis=3)
    assert float(jnp.abs(jnp.where(gates > 0, gates - probs, 0.0)).max()) \
        < 1e-5


def test_moe_aux_loss_balance():
    # perfectly balanced one-hot routing: aux == k == 1
    e = 4
    idx = jnp.arange(16) % e
    probs = jax.nn.one_hot(idx, e)[None]                 # [1, 16, 4]
    dispatch, _ = moe_lib._top_k_dispatch(probs, k=1, capacity=16)
    balanced = moe_lib._aux_loss(probs, dispatch)
    assert float(balanced) == pytest.approx(1.0, rel=1e-5)
    # fully collapsed routing scores E times worse
    probs_bad = jnp.tile(jax.nn.one_hot(jnp.zeros((16,), jnp.int32), e),
                         (1, 1, 1))
    dispatch, _ = moe_lib._top_k_dispatch(probs_bad, k=1, capacity=16)
    collapsed = moe_lib._aux_loss(probs_bad, dispatch)
    assert float(collapsed) == pytest.approx(float(e), rel=1e-5)


def test_rwkv_decay_clamp():
    from repro.models import rwkv6
    cfg = SSM
    model = model_lib.get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    lw = jax.tree.map(lambda x: x[0], params["blocks"])
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model)) * 50
    _, _, _, _, log_w = rwkv6._rkvgw(cfg, x, x, lw)
    assert float(log_w.min()) >= -rwkv6.LOG_W_CLAMP - 1e-6
    assert float(log_w.max()) < 0.0


def test_param_count_close_to_init():
    for cfg in (DENSE, MOE, HYBRID, SSM):
        model = model_lib.get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        # padded vocab + small extras allowed
        assert est == pytest.approx(actual, rel=0.35), cfg.name
