"""Topology generation invariants (core.graphs)."""
import numpy as np
import pytest
from tests._hypothesis import given, st

from repro.core import graphs


@given(st.integers(6, 30), st.integers(2, 5), st.integers(0, 10_000))
def test_rrg_is_simple_and_regular(n, r, seed):
    if n * r % 2 != 0:
        n += 1
    if r >= n:
        return
    topo = graphs.random_regular_graph(n, r, seed)
    topo.validate()
    cap = topo.cap
    assert np.allclose(cap, cap.T)
    assert np.all(np.diag(cap) == 0)
    assert np.all(cap <= 1.0), "simple graph: no multi-edges"
    assert np.all((cap > 0).sum(axis=1) == r)


@given(st.lists(st.integers(1, 6), min_size=6, max_size=20),
       st.integers(0, 10_000))
def test_degree_sequence_respected(degs, seed):
    degs = np.asarray(degs)
    if degs.sum() % 2 != 0:
        degs[0] += 1
    if degs.max() >= len(degs):
        return
    cap = graphs.random_graph_from_degrees(degs, seed).cap
    # capacity-weighted degree holds even if the repair fell back to
    # parallel links for a near-non-graphical sequence
    assert np.all(cap.sum(axis=1) == degs)


def test_multigraph_mode_preserves_degrees():
    degs = [20, 20, 3, 3, 3, 3]   # not graphical as a simple graph
    cap = graphs.random_graph_from_degrees(degs, 0, allow_multi=True).cap
    assert np.all(cap.sum(axis=1) == degs)
    assert np.all(np.diag(cap) == 0)


@pytest.mark.parametrize("bias", [0.2, 1.0, 1.8])
def test_two_cluster_cross_edges_track_bias(bias):
    deg_a = [10] * 12
    deg_b = [6] * 16
    topo = graphs.biased_two_cluster_graph(deg_a, deg_b, bias, seed=1)
    cap, labels = topo.cap, topo.labels
    a = labels == 0
    cross = cap[a][:, ~a].sum()
    sa, sb = 120.0, 96.0
    expected = bias * sa * sb / (sa + sb - 1)
    assert cross == pytest.approx(expected, rel=0.15, abs=4)
    assert np.all((cap > 0).sum(1) == np.concatenate([deg_a, deg_b]))


def test_two_cluster_mismatched_stub_parity_raises():
    # sum(deg_a)=7 odd, sum(deg_b)=8 even: no cross-edge count can leave
    # both clusters with an even leftover stub count.  Used to spin forever
    # in the parity fixup loop; must fail fast instead.
    with pytest.raises(ValueError, match="parity"):
        graphs.biased_two_cluster_graph([3, 2, 2], [2, 2, 2, 2], 1.0, seed=0)


def test_two_cluster_same_parity_still_builds():
    topo = graphs.biased_two_cluster_graph([3, 3, 2], [2, 2, 2, 2], 1.0,
                                           seed=0)
    topo.validate()
    assert topo.cap.sum() == 8 + 8  # all 16 stubs paired


def test_distribute_servers_proportional_and_capped():
    ports = [30, 30, 10, 10, 10]
    srv = graphs.distribute_servers(ports, 45, beta=1.0)
    assert srv.sum() == 45
    assert srv[0] == srv[1] and srv[2] == srv[3] == srv[4]
    assert srv[0] / srv[2] == pytest.approx(3.0, rel=0.25)
    srv2 = graphs.distribute_servers([5, 5, 5], 12)
    assert srv2.sum() == 12 and np.all(srv2 <= 4)


def test_power_law_degrees_in_range():
    ks = graphs.power_law_degrees(200, 4, 48, alpha=2.0, seed=0)
    assert ks.min() >= 4 and ks.max() <= 48
    assert (ks <= 12).mean() > 0.5, "power law should skew small"


def test_power_law_degrees_degenerate_and_invalid_ranges():
    # k_min == k_max: constant draw, not a crash (expansion steps start
    # from single-class pools)
    ks = graphs.power_law_degrees(50, 6, 6, alpha=2.0, seed=0)
    assert np.all(ks == 6)
    with pytest.raises(ValueError, match="k_min"):
        graphs.power_law_degrees(10, 0, 4, alpha=2.0, seed=0)
    with pytest.raises(ValueError, match="empty degree range"):
        graphs.power_law_degrees(10, 5, 4, alpha=2.0, seed=0)


def test_distribute_servers_edge_cases():
    # zero servers: all-zero vector, same length as the pool
    z = graphs.distribute_servers([8, 8, 8], 0)
    assert z.shape == (3,) and z.sum() == 0
    # fewer servers than switches: nothing lost, nothing negative
    few = graphs.distribute_servers([8, 8, 8, 8, 8], 2)
    assert few.sum() == 2 and np.all(few >= 0)
    # empty pool: fine for zero servers, loud otherwise
    assert graphs.distribute_servers([], 0).shape == (0,)
    with pytest.raises(ValueError, match="empty switch pool"):
        graphs.distribute_servers([], 3)
    with pytest.raises(ValueError, match="num_servers"):
        graphs.distribute_servers([8, 8], -1)


def test_connected_components_labels():
    topo = graphs.random_regular_graph(12, 3, seed=0)
    assert len(np.unique(graphs.connected_components(topo))) == 1
    cut = topo.degrade(dead_switches=[0])
    labels = graphs.connected_components(cut)
    assert labels[0] != labels[1], "a dead switch is its own component"
