"""Persistent AOT compile cache (``repro.core.aotcache``): hits serve
serialized executables with zero new XLA compiles, every failure mode
falls back to plain JIT, and results are identical either way."""
import numpy as np
import pytest

from repro.core import aotcache, mcf, traffic
from repro.core.engine import get_engine
from repro.core.graphs import random_regular_graph
from repro.core.plan import compile_cache_sizes


@pytest.fixture(autouse=True)
def _fresh_counters():
    aotcache.reset_stats()
    yield
    aotcache.reset_stats()


def _instance(n=16, servers=3, seed=0):
    t = random_regular_graph(n, 4, seed=seed, servers=servers)
    return t, traffic.make("permutation", t.servers, seed=seed + 1)


def test_miss_then_hit_same_results(tmp_path):
    t, dem = _instance()
    plain = get_engine("dual", iters=50).solve_batch([t] * 2, [dem] * 2)
    eng = get_engine("dual", iters=50, aot_cache=str(tmp_path))
    first = eng.solve_batch([t] * 2, [dem] * 2)
    assert aotcache.stats() == {"compiles": 1, "hits": 0, "misses": 1,
                                "errors": 0}
    second = eng.solve_batch([t] * 2, [dem] * 2)
    assert aotcache.stats()["hits"] == 1
    assert aotcache.stats()["compiles"] == 1
    for a, b, c in zip(plain, first, second):
        assert a.throughput == b.throughput == c.throughput
    assert len(eng._aot.entries()) == 1


def test_second_cache_instance_hits_without_compiling(tmp_path):
    """A fresh AotCache over the same directory (the in-process stand-in
    for a warm process) serves the entry with zero new compiles."""
    t, dem = _instance()
    get_engine("certified", iters=50,
               aot_cache=str(tmp_path)).solve_batch([t], [dem])
    compiled = aotcache.stats()["compiles"]
    assert compiled >= 1
    warm = get_engine("certified", iters=50, aot_cache=str(tmp_path))
    res = warm.solve_batch([t], [dem])
    s = aotcache.stats()
    assert s["compiles"] == compiled, "warm run must not compile"
    assert s["hits"] >= 1
    assert np.isfinite(res[0].throughput)


def test_different_shapes_get_different_entries(tmp_path):
    t1, d1 = _instance(16)
    t2, d2 = _instance(24, seed=3)
    eng = get_engine("dual", iters=50, bucket=None, aot_cache=str(tmp_path))
    eng.solve_batch([t1], [d1])
    eng.solve_batch([t2], [d2])
    assert len(eng._aot.entries()) == 2


def test_corrupt_entry_falls_back_and_heals(tmp_path):
    t, dem = _instance()
    eng = get_engine("dual", iters=50, aot_cache=str(tmp_path))
    ref = eng.solve_batch([t], [dem])[0].throughput
    blob = next(iter(tmp_path.glob("*.aot")))
    blob.write_bytes(b"not a pickle")
    with pytest.warns(RuntimeWarning, match="stale/corrupt"):
        res = eng.solve_batch([t], [dem])[0].throughput
    assert res == ref
    assert aotcache.stats()["errors"] == 1
    # the poisoned entry was dropped and rebuilt
    assert aotcache.stats()["compiles"] == 2
    assert len(eng._aot.entries()) == 1


def test_solver_level_fallback_on_unloadable_function(tmp_path):
    """aot.call on something that cannot be lowered still returns the
    plain call's result (warn-once, counted as an error)."""
    cache = aotcache.AotCache(tmp_path)
    calls = []

    def plain(x, *, k):
        calls.append(x)
        return x * k

    with pytest.warns(RuntimeWarning, match="falling back to jit"):
        out = cache.call(plain, ("test",), (3,), {"k": 2})
    assert out == 6 and calls == [3]
    assert aotcache.stats()["errors"] == 1


def test_resolve_knob_and_env(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_AOT_CACHE", raising=False)
    assert aotcache.resolve(None) is None
    assert aotcache.resolve(False) is None
    c = aotcache.resolve(str(tmp_path))
    assert isinstance(c, aotcache.AotCache) and c.dir == tmp_path
    monkeypatch.setenv("REPRO_AOT_CACHE", "1")
    monkeypatch.setenv("REPRO_AOT_CACHE_DIR", str(tmp_path / "env"))
    env_cache = aotcache.resolve(None)
    assert env_cache is not None and env_cache.dir == tmp_path / "env"
    monkeypatch.setenv("REPRO_AOT_CACHE", "off")
    assert aotcache.resolve(None) is None


def test_compile_cache_sizes_carries_aot_counters(tmp_path):
    sizes = compile_cache_sizes()
    assert sizes["aot.compiles"] == 0 and sizes["aot.hits"] == 0
    t, dem = _instance()
    eng = get_engine("dual", iters=50, aot_cache=str(tmp_path))
    eng.solve_batch([t], [dem])
    eng.solve_batch([t], [dem])
    sizes = compile_cache_sizes()
    assert sizes["aot.compiles"] == 1 and sizes["aot.hits"] == 1


def test_single_solve_ignores_aot(tmp_path):
    t, dem = _instance()
    res = mcf.solve_dual(t, dem, iters=50,
                         aot=aotcache.AotCache(tmp_path))
    assert np.isfinite(res.throughput_ub)
    assert aotcache.stats() == {"compiles": 0, "hits": 0, "misses": 0,
                                "errors": 0}
