"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# tropical (min,+) matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128), (256, 128, 128), (128, 256, 384),
    (130, 200, 150), (129, 129, 129), (64, 64, 64),
])
def test_minplus_shapes(m, k, n):
    key = jax.random.PRNGKey(m * 1000 + k + n)
    a = jax.random.uniform(key, (m, k)) * 10
    b = jax.random.uniform(jax.random.fold_in(key, 1), (k, n)) * 10
    out = ops.minplus_matmul(a, b, 128, True)
    expect = ref.minplus_matmul_ref(a, b)
    np.testing.assert_allclose(out, expect, atol=1e-5)


def test_minplus_with_inf_edges():
    a = jnp.array([[0.0, ops.INF], [1.0, 0.0]])
    out = ops.minplus_matmul(a, a, 128, True)
    np.testing.assert_allclose(out, ref.minplus_matmul_ref(a, a), atol=1e-5)


def test_minplus_gradient_is_argmin_subgradient():
    key = jax.random.PRNGKey(0)
    a = jax.random.uniform(key, (8, 8)) * 5
    b = jax.random.uniform(jax.random.fold_in(key, 1), (8, 8)) * 5

    def f_ker(ab):
        return ops.minplus_matmul(ab[0], ab[1], 128, True).sum()

    def f_ref(ab):
        return ref.minplus_matmul_ref(ab[0], ab[1]).sum()

    g_ker = jax.grad(f_ker)((a, b))
    g_ref = jax.grad(f_ref)((a, b))
    np.testing.assert_allclose(g_ker[0], g_ref[0], atol=1e-5)
    np.testing.assert_allclose(g_ker[1], g_ref[1], atol=1e-5)


def test_minplus_gradient_tie_tolerance_is_scale_invariant():
    """The VJP's tie tolerance must scale with the path lengths: the
    primal MCF solver differentiates APSP at tiny edge lengths, where the
    old absolute 1e-6 tolerance lumped NON-shortest paths into the
    "shortest" set and spread the subgradient across them."""
    key = jax.random.PRNGKey(3)
    a = jax.random.uniform(key, (8, 8), minval=0.1) * 5
    b = jax.random.uniform(jax.random.fold_in(key, 1), (8, 8),
                           minval=0.1) * 5

    def f(ab, scale):
        return ops.minplus_matmul(ab[0] * scale, ab[1] * scale,
                                  128, True).sum()

    g_unit = jax.grad(f)((a, b), 1.0)
    g_tiny = jax.grad(f)((a, b), 1e-6)
    # scaling all lengths never changes which paths are shortest, so the
    # argmin subgradient pattern must match (cotangents scale linearly)
    np.testing.assert_allclose(g_tiny[0], g_unit[0] * 1e-6, rtol=1e-4)
    np.testing.assert_allclose(g_tiny[1], g_unit[1] * 1e-6, rtol=1e-4)


@settings(max_examples=8)
@given(st.integers(2, 40), st.integers(2, 40), st.integers(0, 99))
def test_minplus_small_property(m, n, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.uniform(key, (m, n)) * 3
    b = jax.random.uniform(jax.random.fold_in(key, 7), (n, m)) * 3
    out = ops.minplus_matmul(a, b, 128, True)
    np.testing.assert_allclose(out, ref.minplus_matmul_ref(a, b), atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention (GQA, causal)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,lq,lk,hq,hkv,d,causal", [
    (1, 128, 128, 4, 4, 64, True),
    (2, 256, 256, 8, 2, 64, True),
    (1, 256, 256, 4, 1, 128, True),     # MQA
    (2, 128, 256, 4, 4, 64, True),      # cross lengths (cached prefix)
    (1, 256, 256, 4, 4, 64, False),
    (1, 200, 300, 4, 2, 64, True),      # non-multiple-of-tile
])
def test_flash_attention_vs_ref(b, lq, lk, hq, hkv, d, causal):
    keys = jax.random.split(jax.random.PRNGKey(lq + lk), 3)
    q = jax.random.normal(keys[0], (b, lq, hq, d))
    k = jax.random.normal(keys[1], (b, lk, hkv, d))
    v = jax.random.normal(keys[2], (b, lk, hkv, d))
    out = ops.flash_attention(q, k, v, causal=causal)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(keys[0], (1, 128, 4, 64), jnp.bfloat16)
    k = jax.random.normal(keys[1], (1, 128, 2, 64), jnp.bfloat16)
    v = jax.random.normal(keys[2], (1, 128, 2, 64), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# blockwise jnp attention (the dry-run stand-in) vs the same oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 32])
def test_blockwise_attention_matches_ref(window):
    from repro.models import layers
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(keys[0], (2, 96, 4, 32))
    k = jax.random.normal(keys[1], (2, 96, 2, 32))
    v = jax.random.normal(keys[2], (2, 96, 2, 32))
    out = layers.attention(q, k, v, causal=True, window=window, block=32)
    if window == 0:
        expect = ref.flash_attention_ref(q, k, v, causal=True)
    else:
        qi = jnp.arange(96)[:, None]
        kj = jnp.arange(96)[None, :]
        bias = jnp.where((kj <= qi) & (kj > qi - window), 0.0, -jnp.inf)
        expect = ref.flash_attention_ref(q, k, v, causal=False,
                                         bias=bias[None, None, None])
    np.testing.assert_allclose(out, expect, atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# chunked WKV-6 (rwkv) kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,t,n", [(2, 64, 16), (3, 70, 32), (1, 32, 64)])
def test_wkv_kernel_vs_serial_ref(bh, t, n):
    ks = jax.random.split(jax.random.PRNGKey(t + n), 4)
    r = jax.random.normal(ks[0], (bh, t, n))
    k = jax.random.normal(ks[1], (bh, t, n))
    v = jax.random.normal(ks[2], (bh, t, n))
    log_w = -jnp.clip(jnp.exp(jax.random.normal(ks[3], (bh, t, n))),
                      1e-6, 2.5)
    u = jax.random.normal(jax.random.fold_in(ks[0], 1), (n,)) * 0.5
    out = ops.wkv_chunked(r, k, v, log_w, u)
    expect = ref.wkv_ref(r, k, v, log_w, u)
    np.testing.assert_allclose(out, expect, atol=2e-3, rtol=2e-3)


def test_wkv_strong_decay_forgets():
    """with saturated decay the state forgets: outputs ~ diag term only."""
    bh, t, n = 1, 64, 16
    r = jnp.ones((bh, t, n))
    k = jnp.ones((bh, t, n))
    v = jnp.ones((bh, t, n))
    log_w = jnp.full((bh, t, n), -2.5)
    u = jnp.zeros((n,))
    out = ops.wkv_chunked(r, k, v, log_w, u)
    # geometric series of decayed contributions: bounded well below t*n
    assert float(out.max()) < n * 2.0
