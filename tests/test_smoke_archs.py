"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced same-family config, runs one forward + one train step + one decode
step on CPU with finite outputs and the right shapes."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, applicable_shapes, get_smoke
from repro.data import make_batch
from repro.models import model as model_lib
from repro.optim import AdamW


@pytest.mark.parametrize("arch", sorted(ARCH_IDS), ids=str)
def test_smoke_forward_train_decode(arch):
    cfg = get_smoke(arch)
    model = model_lib.get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    b, s = 2, 32

    batch = make_batch(cfg, b, s, step=0, accum=1)
    jbatch = {k: jnp.asarray(v) for k, v in batch.items()}

    # forward
    fwd_in = {k: v[0] for k, v in jbatch.items()}
    logits, aux, _ = model.forward(params, fwd_in)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch

    # one train step
    opt = AdamW(lr=1e-3)
    step = model_lib.make_train_step(cfg, opt, accum=1)
    params2, _, metrics = jax.jit(step)(params, opt.init(params), jbatch)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert bool(jnp.isfinite(metrics["grad_norm"])), arch
    # parameters actually moved
    moved = jax.tree.map(lambda a, c: float(jnp.abs(a - c).max()),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0

    # decode one token against a prefilled cache (text-only path)
    toks = jnp.asarray(batch["tokens"][0][:, : s // 2])
    if cfg.frontend == "patch":
        _, cache = model.prefill(
            params, {"tokens": toks,
                     "patch_embeds": jnp.asarray(batch["patch_embeds"][0]),
                     "positions": jnp.asarray(
                         batch["positions"][0][:, :, : s // 2
                                               + cfg.frontend_len])},
            max_len=s)
    else:
        _, cache = model.prefill(params, {"tokens": toks}, max_len=s)
    lg, cache = model.decode_step(params, cache,
                                  jnp.zeros((b, 1), jnp.int32))
    assert lg.shape == (b, cfg.padded_vocab)
    assert bool(jnp.isfinite(lg).all()), arch


@pytest.mark.parametrize("arch", sorted(ARCH_IDS), ids=str)
def test_shape_applicability(arch):
    cfg = get_smoke(arch)
    shapes = applicable_shapes(cfg.family)
    assert "train_4k" in shapes
    if cfg.family in ("hybrid", "ssm"):
        assert "long_500k" in shapes
    else:
        assert "long_500k" not in shapes


def test_full_configs_have_exact_assigned_dims():
    from repro.configs import get_config
    expect = {
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }
    for arch, (nl, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (nl, d, h, kv, ff, v), arch
    rwkv = get_config("rwkv6-7b")
    assert (rwkv.num_layers, rwkv.d_model, rwkv.d_ff,
            rwkv.vocab_size) == (32, 4096, 14336, 65536)
    moe = get_config("granite-moe-3b-a800m")
    assert (moe.num_experts, moe.experts_per_token) == (40, 8)
    l4 = get_config("llama4-scout-17b-a16e")
    assert (l4.num_experts, l4.experts_per_token) == (16, 1)
