"""Adversarial worst-TM search (core.adversarial) contract tests.

Pins the four claims the subsystem makes:

1. **Hose feasibility by construction** — every candidate TM the search
   emits (not just the winner) satisfies the hose caps: zero diagonal,
   row sums ≤ per-switch servers, column sums ≤ per-switch servers.
2. **The adversary never loses to the baseline** — lane 0 of every round
   is the uniform baseline, so the worst-found certified bound is ≤ the
   baseline's; on the biased two-cluster family it is STRICTLY below
   (the acceptance criterion — sampled traffic hides the weak cut).
3. **Seeded determinism** — same seed, same TM, same bracket.
4. **One ``BatchPlan.execute`` per round + shared compile keys** — the
   same execute-count/compile-key pins ``tests/test_design.py`` uses:
   ``executes == 1 + rounds`` (one per search round plus ONE primal
   certification) and a single (padded_n, lanes) compile key for the
   whole search, certification included.
"""
import numpy as np
import pytest

from repro.core import graphs, traffic
from repro.core.adversarial import (find_worst_tm, hose_feasible,
                                    hose_violation)
from repro.core.engine import get_engine
from repro.core.plan import SOLVERS, BatchPlan

# float32 solver lanes + Sinkhorn-style projection: feasibility holds to
# float32 roundoff, pinned here in absolute flow units
TOL = 1e-4


@pytest.fixture(scope="module")
def two_cluster():
    return graphs.biased_two_cluster_graph([6] * 8, [4] * 8, cross_bias=0.6,
                                           seed=1, servers=2)


@pytest.fixture(scope="module")
def search(two_cluster):
    """One worst-TM search reused across the contract tests."""
    return find_worst_tm(two_cluster, seed=0, rounds=3, candidates=4,
                         iters=200, keep_fleet=True)


# ---------------------------------------------------------------------------
# hose feasibility
# ---------------------------------------------------------------------------

def test_hose_feasible_for_arbitrary_logits():
    rng = np.random.default_rng(7)
    servers = np.array([3, 0, 2, 5, 1, 0, 4])
    for _ in range(5):
        logits = rng.normal(0, 5, size=(7, 7))   # wild logits, any scale
        dem = hose_feasible(logits, servers)
        assert hose_violation(dem, servers) <= TOL
        # zero-server switches source and sink nothing
        assert dem[1].sum() == 0 and dem[:, 1].sum() == 0
        assert dem[5].sum() == 0 and dem[:, 5].sum() == 0
        # rows are scaled UP toward the cap before the final column clip,
        # so the TM cannot collapse toward zero — a shrunk TM would game
        # the per-unit-demand throughput.  The clip gives back some row
        # mass; pin that the total stays a solid fraction of the cap.
        live = servers > 0
        assert dem.sum() >= 0.5 * servers[live].sum()
        assert np.all(dem.sum(axis=1)[live] <= servers[live] * (1 + 1e-5))


def test_every_emitted_candidate_is_hose_feasible(search, two_cluster):
    servers = two_cluster.servers
    assert len(search.fleet) == 3 * 3   # (candidates - 1) x rounds
    for dem in search.fleet:
        assert hose_violation(dem, servers) <= TOL
    assert hose_violation(search.tm, servers) <= TOL


# ---------------------------------------------------------------------------
# adversarial <= uniform, strictly on the two-cluster family
# ---------------------------------------------------------------------------

def test_worst_tm_beats_uniform_baseline(search):
    # lane 0 is the baseline, so the min can never sit above it ...
    assert search.ub <= search.baseline_ub + 1e-6
    # ... and on biased_two_cluster the found TM is certified STRICTLY
    # below the uniform-permutation value: adv ub < baseline lb means
    # theta_adv < theta_uniform is provable, not just suggested
    assert search.ub < search.baseline_lb
    assert search.uniform_gap_pct > 0
    # brackets are ordered
    assert search.lb <= search.ub + 1e-6
    assert search.baseline_lb <= search.baseline_ub + 1e-6


def test_search_actually_descends(search):
    # the per-round minimum is monotone non-increasing by construction,
    # and the gradient steps must have found something better than the
    # round-1 fleet (pinning that the demand gradient is wired through)
    mins = [h["best_ub"] for h in search.history]
    assert all(a >= b - 1e-9 for a, b in zip(mins, mins[1:]))
    assert mins[-1] < mins[0]


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_seeded_determinism(search, two_cluster):
    again = find_worst_tm(two_cluster, seed=0, rounds=3, candidates=4,
                          iters=200, keep_fleet=True)
    np.testing.assert_array_equal(search.tm, again.tm)
    assert search.ub == again.ub and search.lb == again.lb
    assert search.history == again.history


# ---------------------------------------------------------------------------
# execute / compile-key contract (the test_design.py pins)
# ---------------------------------------------------------------------------

def test_one_execute_per_round_and_shared_compile_keys(search):
    s = search.stats
    assert s["search_executes"] == s["rounds"] == 3
    assert s["certify_executes"] == 1
    assert s["executes"] == 1 + s["rounds"]
    # every round AND the certification ride the round-one plan: exactly
    # one (padded_n, lanes) compile key for the whole search
    assert len(s["compile_keys"]) == 1
    assert s["last_plan"]["instances"] == s["candidates"]


def test_dual_demgrad_solver_registered_and_crops_gradients(two_cluster):
    assert "dual-demgrad" in SOLVERS
    n = two_cluster.n
    dem = traffic.make("permutation", two_cluster.servers, 3)
    plan = BatchPlan.build([two_cluster], [dem], devices=1)
    (solved,) = plan.execute(solver="dual-demgrad", iters=60)
    g = solved.meta["dem_grad"]
    # array-valued meta survives unpacking, cropped to the real node count
    # (the pow2 bucket pads 16 -> 16 here, but the contract is the crop)
    assert isinstance(g, np.ndarray) and g.shape == (n, n)
    # Danskin gradient of the log-ratio bound w.r.t. demand is
    # -dist(s, t)/alpha on valid pairs: non-positive everywhere, strictly
    # negative off-diagonal (connected graph), zero on the diagonal
    assert np.all(g <= 1e-9)
    assert np.all(np.abs(np.diag(g)) <= 1e-9)
    off = ~np.eye(n, dtype=bool)
    assert np.all(g[off] < 0)


# ---------------------------------------------------------------------------
# input validation + registry plumbing
# ---------------------------------------------------------------------------

def test_find_worst_tm_rejects_bad_inputs(two_cluster):
    with pytest.raises(ValueError, match="Topology"):
        find_worst_tm(np.asarray(two_cluster.cap))
    with pytest.raises(ValueError, match="rounds >= 1"):
        find_worst_tm(two_cluster, rounds=0)
    with pytest.raises(ValueError, match="candidates >= 2"):
        find_worst_tm(two_cluster, candidates=1)
    lonely = graphs.random_regular_graph(8, 3, seed=0,
                                         servers=[5, 0, 0, 0, 0, 0, 0, 0])
    with pytest.raises(ValueError, match=">= 2 switches"):
        find_worst_tm(lonely)
    with pytest.raises(ValueError, match="baseline TM"):
        find_worst_tm(two_cluster, baseline=np.ones((3, 3)))


def test_traffic_registry_entry(two_cluster):
    tm = traffic.make("adversarial", two_cluster.servers, seed=0,
                      topo=two_cluster, rounds=1, candidates=2, iters=80)
    assert tm.shape == (two_cluster.n, two_cluster.n)
    assert hose_violation(tm, two_cluster.servers) <= TOL
    with pytest.raises(ValueError, match="topo"):
        traffic.make("adversarial", two_cluster.servers, seed=0)


def test_engine_returns_certified_bracket(two_cluster):
    eng = get_engine("adversarial", rounds=2, candidates=3, iters=150)
    res = eng.solve(two_cluster)
    assert res.bound == "bracket" and res.engine == "adversarial"
    m = res.meta
    assert m["lb"] <= m["ub"] + 1e-6
    assert res.throughput == m["ub"]
    assert hose_violation(m["tm"], two_cluster.servers) <= TOL
    assert m["uniform_gap_pct"] >= 0
    assert m["executes"] == 1 + m["rounds"]
    assert m["baseline_lb"] <= m["baseline_ub"] + 1e-6


def test_engine_coarsens_server_expanded_topologies():
    topo = graphs.random_regular_graph(10, 3, seed=2,
                                       servers=3).with_server_nodes()
    res = get_engine("adversarial", rounds=1, candidates=2,
                     iters=80).solve(topo)
    # the search runs at switch level: the TM is 10x10, not 40x40
    assert res.meta["tm"].shape == (10, 10)
    assert res.throughput > 0


# ---------------------------------------------------------------------------
# robust design mode
# ---------------------------------------------------------------------------

def test_design_optimize_robust_mode():
    from repro.core import vl2
    from repro.design import VL2Space, optimize

    spec = vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=4)
    res = optimize(VL2Space(spec, spec.n_tor_full), rounds=1, fleet=3,
                   elite=2, runs=2, seed=0,
                   robust={"rounds": 1, "candidates": 2, "iters": 60})
    r = res.stats["robust"]
    assert r is not None and r["rounds"] == 1 and r["candidates"] == 2
    # one adversarial search (1 round + 1 certify = 2 executes) per
    # unique certified candidate
    assert r["executes"] % 2 == 0 and r["executes"] >= 4
    # lb/ub are now the worst-TM bracket of each candidate
    for ev in res.elites + [res.reference]:
        assert ev.lb is not None and ev.ub is not None
        assert ev.lb <= ev.ub + 1e-6
    assert res.best.lb == max(e.lb for e in res.elites + [res.reference])
    # the sampled-traffic execute contract is untouched by robust mode
    assert res.stats["search_executes"] == 1 + res.stats["rounds"]
    assert res.stats["certify_executes"] == 1
