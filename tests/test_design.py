"""Designer tests: move-kernel feasibility invariants, seeded determinism
and resume, the designed-vs-recipe non-regression on a tiny VL2 spec, and
the one-BatchPlan-execute-per-round contract."""
import numpy as np
import pytest

from repro.core import heterogeneous as het, vl2
from repro.core.engine import DualEngine
from repro.core.plan import BatchPlan
from repro.design import (MOVES, TwoClassSpace, VL2Space, move_servers,
                          optimize, perturb_bias, swap_edges)

VSPEC = vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=4)
# 3 + 7 = 10 switches — the same node count as the tiny VL2 space above, so
# (with matching fleet x runs lane counts) every search in this module
# reuses ONE compiled dual program and ONE compiled primal program
TSPEC = het.TwoClassSpec(n_large=3, k_large=12, n_small=7, k_small=5,
                         num_servers=25)


def _cheap_engine():
    return DualEngine(iters=40, tol=1e-3)


@pytest.fixture(scope="module")
def vl2_result():
    """One shared tiny VL2 search (determinism re-runs it below)."""
    return optimize(VL2Space(VSPEC, VSPEC.n_tor_full),
                    engine=_cheap_engine(), moves=("swap",), rounds=2,
                    fleet=4, elite=2, runs=2, seed=0)


# --- move kernels -----------------------------------------------------------

def _check_same_equipment(old, new):
    """A move may rewire links but never mint ports, capacity or servers."""
    assert np.allclose(new.cap, new.cap.T)
    assert np.all(np.diag(new.cap) == 0)
    assert np.all(new.cap >= 0)
    assert np.allclose(new.cap.sum(axis=0), old.cap.sum(axis=0)), \
        "per-switch attached capacity (ports x line speed) must be preserved"
    assert int(new.servers.sum()) == int(old.servers.sum())


@pytest.mark.parametrize("seed", range(5))
def test_swap_preserves_degrees_and_forbidden_pairs(seed):
    space = VL2Space(VSPEC, VSPEC.n_tor_full)
    cand = space.initial(seed)
    new = swap_edges(cand, np.random.default_rng(seed), space)
    assert new is not None and new.origin == "swap"
    _check_same_equipment(cand.topo, new.topo)
    assert not np.array_equal(new.topo.cap, cand.topo.cap), \
        "a successful swap must change the wiring"
    tor = new.topo.labels == 0
    assert np.all(new.topo.cap[np.ix_(tor, tor)] == 0), \
        "VL2 swaps must never create ToR-ToR links"


@pytest.mark.parametrize("seed", range(3))
def test_parametric_moves_rebuild_feasible_topologies(seed):
    space = TwoClassSpace(TSPEC)
    cand = space.initial(seed)
    rng = np.random.default_rng(seed)
    moved = move_servers(cand, rng, space)
    assert moved is not None and moved.origin == "servers"
    assert int(moved.topo.servers.sum()) == TSPEC.num_servers
    lo, hi = space.param_bounds["servers_on_large"]
    assert lo <= moved.params["servers_on_large"] <= hi
    moved.topo.validate()

    biased = perturb_bias(cand, rng, space)
    assert biased is not None and biased.origin == "bias"
    lo, hi = space.param_bounds["cross_bias"]
    assert lo <= biased.params["cross_bias"] <= hi
    biased.topo.validate()


def test_parametric_moves_skip_nonparametric_spaces():
    space = VL2Space(VSPEC, VSPEC.n_tor_full)
    cand = space.initial(0)
    rng = np.random.default_rng(0)
    assert move_servers(cand, rng, space) is None
    assert perturb_bias(cand, rng, space) is None
    assert set(MOVES) == {"swap", "servers", "bias"}


# --- optimizer --------------------------------------------------------------

def test_seeded_determinism(vl2_result):
    again = optimize(VL2Space(VSPEC, VSPEC.n_tor_full),
                     engine=_cheap_engine(), moves=("swap",), rounds=2,
                     fleet=4, elite=2, runs=2, seed=0)
    assert [e.score for e in again.elites] == \
        [e.score for e in vl2_result.elites]
    assert [e.lb for e in again.elites] == [e.lb for e in vl2_result.elites]
    for a, b in zip(again.elites, vl2_result.elites):
        assert np.array_equal(a.cand.topo.cap, b.cand.topo.cap)
    assert again.history == vl2_result.history


def test_resume_matches_uninterrupted(vl2_result):
    first = optimize(VL2Space(VSPEC, VSPEC.n_tor_full),
                     engine=_cheap_engine(), moves=("swap",), rounds=1,
                     fleet=4, elite=2, runs=2, seed=0)
    resumed = optimize(VL2Space(VSPEC, VSPEC.n_tor_full),
                       engine=_cheap_engine(), moves=("swap",), rounds=1,
                       fleet=4, elite=2, runs=2, seed=0, state=first.state)
    assert [e.score for e in resumed.elites] == \
        [e.score for e in vl2_result.elites]
    assert resumed.state.rounds_done == 2


@pytest.mark.parametrize("seed", [0, 4])
def test_resume_matches_uninterrupted_with_parametric_moves(seed):
    """Resume must pair the rng stream with the same elite parents as an
    uninterrupted run even when the certified-lb ordering disagrees with
    the search-score ordering (seed 4 used to diverge: the state stored
    lb-sorted elites while the loop ranked by dual score)."""
    kw = dict(engine=_cheap_engine(), rounds=1, fleet=4, elite=2, runs=2,
              seed=seed)
    straight = optimize(TwoClassSpace(TSPEC), rounds=2, **{
        k: v for k, v in kw.items() if k != "rounds"})
    first = optimize(TwoClassSpace(TSPEC), **kw)
    resumed = optimize(TwoClassSpace(TSPEC), state=first.state, **kw)
    assert resumed.history == straight.history[-1:]
    assert [e.score for e in resumed.state.elites] == \
        [e.score for e in straight.state.elites]
    for a, b in zip(resumed.state.elites, straight.state.elites):
        assert np.array_equal(a.cand.topo.cap, b.cand.topo.cap)


def test_designed_vl2_never_below_recipe(vl2_result):
    """The acceptance criterion: the optimizer's certified lower bound is
    >= the hand-coded ``rewired_vl2_topology`` recipe's certified bound
    (the recipe is candidate 0 and stays in the final certification)."""
    assert vl2_result.best.lb is not None
    assert vl2_result.best.lb >= vl2_result.reference.lb
    assert vl2_result.best.lb <= vl2_result.best.ub
    # the reference really is the recipe wiring
    recipe = vl2.rewired_vl2_topology(VSPEC, VSPEC.n_tor_full, seed=0)
    assert np.array_equal(vl2_result.reference.cand.topo.cap, recipe.cap)


def test_one_execute_per_round_and_shared_compile_keys(vl2_result):
    s = vl2_result.stats
    # init eval + one execute per round; exactly one certification pass
    assert s["search_executes"] == 1 + s["rounds"] == 3
    assert s["certify_executes"] == 1
    assert s["executes"] == 4
    # same-size candidates share compile keys: one (padded_n, lanes) shape
    # for every search round + one for the (elite+1)-lane certify pass
    assert len(s["compile_keys"]) == 2
    assert s["last_plan"]["instances"] == 4 * 2   # fleet x runs


def test_optimizer_rejects_bad_inputs():
    space = VL2Space(VSPEC, VSPEC.n_tor_full)
    with pytest.raises(ValueError, match="unknown move"):
        optimize(space, moves=("warp",), rounds=0)
    with pytest.raises(ValueError, match="BatchPlan"):
        optimize(space, engine="exact", rounds=0)
    with pytest.raises(ValueError, match="fleet"):
        optimize(space, fleet=0)


def test_two_class_search_improves_or_matches_recipe():
    res = optimize(TwoClassSpace(TSPEC), engine=_cheap_engine(),
                   rounds=1, fleet=4, elite=2, runs=2, seed=1)
    assert res.best.lb >= res.reference.lb
    assert res.reference.cand.params["cross_bias"] == 1.0


# --- plan refill (the round-to-round fast path) -----------------------------

def test_plan_refill_reuses_structure_and_checks_shapes():
    topos = [vl2.rewired_vl2_topology(VSPEC, VSPEC.n_tor_full, s)
             for s in range(3)]
    dems = [np.ones((t.n, t.n)) - np.eye(t.n) for t in topos]
    plan = BatchPlan.build(topos, dems, devices=1)
    refilled = plan.refill(list(reversed(topos)), dems)
    assert refilled.chunks is plan.chunks
    assert refilled.stats.compile_keys == plan.stats.compile_keys
    with pytest.raises(ValueError, match="refill needs"):
        plan.refill(topos[:2], dems[:2])
    small = vl2.vl2_topology(vl2.VL2Spec(d_a=2, d_i=2))
    with pytest.raises(ValueError, match="nodes"):
        plan.refill([small] * 3, dems)
