import os
import pathlib
import time

import numpy as np
import pytest

from tests._seedcheck import unseeded_rng_calls

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:        # hypothesis is an optional test extra
    settings = None

if settings is not None:
    # CPU container: small example counts, no deadlines (jit compiles inside)
    settings.register_profile(
        "ci", max_examples=15, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")


_SESSION_T0 = time.time()


def _budget_seconds() -> float:
    """Wall-clock budget for the whole session, from
    ``$PYTEST_BUDGET_SECONDS`` (0 / unset = no budget).  CI sets 660 —
    the 11-minute tier-1 budget on a 2-core runner."""
    try:
        return float(os.environ.get("PYTEST_BUDGET_SECONDS", "0"))
    except ValueError:
        return 0.0


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    budget = _budget_seconds()
    if budget <= 0:
        return
    elapsed = time.time() - _SESSION_T0
    status = "within" if elapsed <= budget else "OVER"
    terminalreporter.write_line(
        f"tier-1 time budget: {elapsed:.0f}s of {budget:.0f}s ({status} "
        "budget)")


def pytest_sessionfinish(session, exitstatus):
    budget = _budget_seconds()
    if budget <= 0:
        return
    elapsed = time.time() - _SESSION_T0
    if elapsed > budget and session.exitstatus == 0:
        # fail the run: a green-but-slow suite silently eats the CI budget
        session.exitstatus = 1


def pytest_collection_finish(session):
    """Seed audit: fail the session if any collected test file constructs
    unseeded randomness (``default_rng()`` / ``RandomState()`` /
    ``np.random.seed()`` with no arguments) — see ``tests/_seedcheck.py``."""
    files = sorted({pathlib.Path(str(item.fspath))
                    for item in session.items
                    if str(item.fspath).endswith(".py")})
    problems = []
    for f in files:
        problems += unseeded_rng_calls(f.read_text(), str(f))
    if problems:
        raise pytest.UsageError(
            "unseeded rng construction in test files:\n  "
            + "\n  ".join(problems))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
