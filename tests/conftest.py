import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:        # hypothesis is an optional test extra
    settings = None

if settings is not None:
    # CPU container: small example counts, no deadlines (jit compiles inside)
    settings.register_profile(
        "ci", max_examples=15, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
