"""BENCH_<name>.json artifact schema pinning.

``benchmarks.run`` writes one machine-readable artifact per figure; CI
uploads them and downstream tooling tracks the perf trajectory across PRs.
These tests pin the key sets (top-level payload, the per-figure stats
block, plan-stats, per-row bracket columns) so artifact consumers do not
break silently when the benchmark harness evolves.
"""
import json

import numpy as np
import pytest

from benchmarks import (adversarial_bench, design_bench, lifecycle_bench,
                        routing_bench, scale_bench)
from benchmarks.common import (bench_extra, bracket_cols, max_bracket_gap,
                               write_bench_json)
from repro.core import graphs, traffic
from repro.core.engine import DualEngine, SweepPoint
from repro.core.plan import PlanStats

# the pinned contracts -------------------------------------------------------

PAYLOAD_KEYS = {"name", "generated_unix", "wall_s", "headline", "rows"}
EXTRA_KEYS = {"scale", "engine", "compiles", "last_plan", "max_gap"}
PLAN_STATS_KEYS = {"instances", "buckets", "chunks", "devices", "max_lanes",
                   "lanes_total", "lanes_padded", "compile_keys"}
DESIGN_ROW_KEYS = {"figure", "space", "rounds", "fleet", "elite", "runs",
                   "executes", "search_executes", "compile_keys",
                   "instances_per_round", "recipe_lb", "best_lb", "best_ub",
                   "design_gain_pct", "wall_s"}
DESIGN_EXTRA_KEYS = {"compile_keys", "last_plan", "rounds", "fleet"}
LIFECYCLE_ROW_KEYS = {"figure", "family", "kind", "fraction", "trials",
                      "lb_q10", "lb_med", "lb_q90", "ub_mean", "gap_max",
                      "reachable_mean", "dead_trials"}
LIFECYCLE_EXTRA_KEYS = {"compile_keys", "executes", "refills", "last_plan",
                        "expansion"}
EXPANSION_STEP_KEYS = {"step", "nodes", "new_switches", "new_ports",
                       "spare_ports", "recabled", "lb", "ub", "lb_source",
                       "chose"}
SCALE_ROW_KEYS = {"figure", "section", "backend", "label", "n", "padded_n",
                  "ok", "wall_s", "mem_gb", "peak_rss_mb", "d_max", "rounds",
                  "lb", "ub", "compiles", "hits"}
SCALE_EXTRA_KEYS = {"mem_budget_gb", "time_budget_s", "frontier",
                    "coarsen_equal", "warm_over_cold", "last_plan"}
ADVERSARIAL_ROW_KEYS = {"figure", "family", "n", "rounds", "candidates",
                        "executes", "search_executes", "compile_keys",
                        "baseline_lb", "baseline_ub", "adversarial_lb",
                        "adversarial_ub", "uniform_gap_pct", "wall_s"}
ADVERSARIAL_EXTRA_KEYS = {"compile_keys", "last_plan", "rounds", "candidates"}
ROUTING_ROW_KEYS = {"figure", "family", "n", "pattern", "runs", "k",
                    "ideal_lb", "ideal_ub", "ecmp_lb", "ksp_lb",
                    "ecmp_gap_pct", "ksp_gap_pct", "executes",
                    "compile_keys", "wall_s"}
ROUTING_EXTRA_KEYS = {"compile_keys", "last_plan", "k", "iters",
                      "round2_new_compiles"}


def _write(tmp_path, rows, extra=None):
    path = write_bench_json("schema_probe", rows, headline="h", wall_s=1.2,
                            extra=extra, out_dir=str(tmp_path))
    with open(path) as f:
        return path, json.load(f)


def test_payload_top_level_keys(tmp_path):
    rows = [{"figure": "fig5", "bias": 0.5, "throughput": 1.0}]
    path, payload = _write(tmp_path, rows)
    assert path.endswith("BENCH_schema_probe.json")
    assert set(payload) == PAYLOAD_KEYS
    assert payload["rows"] == rows
    assert payload["headline"] == "h" and payload["wall_s"] == 1.2


def test_payload_with_figure_stats_block(tmp_path):
    extra = bench_extra(scale="small", engine="certified",
                        compiles={"dual.solve_batch": 1}, last_plan=None)
    extra["max_gap"] = 0.03
    rows = [{"figure": "fig5", "bias": 0.5, "throughput": 1.0, "gap": 0.03}]
    _, payload = _write(tmp_path, rows, extra)
    assert set(payload) == PAYLOAD_KEYS | EXTRA_KEYS
    assert payload["max_gap"] == 0.03
    assert payload["engine"] == "certified"


def test_bench_extra_key_contract():
    extra = bench_extra(scale="small", engine="dual", compiles={},
                        last_plan=None)
    assert set(extra) == EXTRA_KEYS


def test_plan_stats_keys_and_json_round_trip(tmp_path):
    topo = graphs.random_regular_graph(8, 3, 0, servers=2)
    dem = traffic.make("permutation", topo.servers, 1)
    eng = DualEngine(iters=5, devices=1)
    eng.solve_batch([topo], [dem])
    stats = eng.last_plan.as_dict()
    assert isinstance(eng.last_plan, PlanStats)
    assert set(stats) == PLAN_STATS_KEYS
    # the dict must survive the artifact's JSON encoding (compile_keys is
    # a tuple of tuples; json maps it to nested lists)
    _, payload = _write(tmp_path, [{"figure": "probe", "x": 1}],
                        bench_extra(scale="small", engine="dual",
                                    compiles={}, last_plan=stats))
    assert set(payload["last_plan"]) == PLAN_STATS_KEYS
    assert payload["last_plan"]["instances"] == 1
    assert payload["last_plan"]["compile_keys"] == [[8, 1]]


def test_max_bracket_gap_and_bracket_cols():
    pts = [SweepPoint(0.5, 1.0, 0.0, (1.0,), lb_mean=0.97, gap_max=0.03),
           SweepPoint(1.0, 1.1, 0.0, (1.1,), lb_mean=1.05, gap_max=0.045)]
    rows = [{"figure": "f", "x": p.x, "throughput": p.mean,
             **bracket_cols(p)} for p in pts]
    assert all(r["gap"] == p.gap_max for r, p in zip(rows, pts))
    assert max_bracket_gap(rows) == pytest.approx(0.045)
    # engines without brackets add no column and report no gap
    bare = SweepPoint(0.5, 1.0, 0.0, (1.0,))
    assert bracket_cols(bare) == {}
    assert max_bracket_gap([{"figure": "f", "x": 1.0}]) is None


def test_design_artifact_schema(tmp_path):
    """BENCH_design.json: the designer bench's row/extra key sets are
    pinned here AND asserted at generation time inside ``bench`` itself
    (CI's ``design_bench --smoke`` runs the real thing; this test keeps
    the contract visible and the payload JSON-able without paying for a
    search)."""
    assert design_bench.DESIGN_ROW_KEYS == DESIGN_ROW_KEYS
    assert design_bench.DESIGN_EXTRA_KEYS == DESIGN_EXTRA_KEYS
    row = dict.fromkeys(DESIGN_ROW_KEYS, 1)
    row.update(figure="design", space="vl2")
    extra = {"compile_keys": [[10, 8], [10, 6]],
             "last_plan": None, "rounds": 1, "fleet": 4}
    path = write_bench_json("design", [row], headline="h", wall_s=0.1,
                            extra=extra, out_dir=str(tmp_path))
    with open(path) as f:
        payload = json.load(f)
    assert path.endswith("BENCH_design.json")
    assert set(payload) == PAYLOAD_KEYS | DESIGN_EXTRA_KEYS
    assert set(payload["rows"][0]) == DESIGN_ROW_KEYS
    assert payload["compile_keys"] == [[10, 8], [10, 6]]


def test_adversarial_artifact_schema(tmp_path):
    """BENCH_adversarial.json: the worst-TM bench's row/extra key sets are
    pinned here AND asserted at generation time inside ``bench`` (CI's
    ``adversarial_bench --smoke`` runs the real search; this test keeps
    the contract visible and the payload JSON-able without paying for
    one)."""
    assert adversarial_bench.ADVERSARIAL_ROW_KEYS == \
        frozenset(ADVERSARIAL_ROW_KEYS)
    assert adversarial_bench.ADVERSARIAL_EXTRA_KEYS == \
        frozenset(ADVERSARIAL_EXTRA_KEYS)
    row = dict.fromkeys(ADVERSARIAL_ROW_KEYS, 1)
    row.update(figure="adversarial", family="two_cluster",
               uniform_gap_pct=18.4)
    extra = {"compile_keys": [[16, 4]], "last_plan": None,
             "rounds": 2, "candidates": 4}
    path = write_bench_json("adversarial", [row], headline="h", wall_s=0.1,
                            extra=extra, out_dir=str(tmp_path))
    with open(path) as f:
        payload = json.load(f)
    assert path.endswith("BENCH_adversarial.json")
    assert set(payload) == PAYLOAD_KEYS | ADVERSARIAL_EXTRA_KEYS
    assert set(payload["rows"][0]) == ADVERSARIAL_ROW_KEYS
    assert payload["compile_keys"] == [[16, 4]]


def test_routing_artifact_schema(tmp_path):
    """BENCH_routing.json: the routing-gap bench's row/extra key sets are
    pinned here AND asserted at generation time inside ``bench`` (CI's
    ``routing_bench --smoke`` runs the real trio; this test keeps the
    contract visible and the payload JSON-able without paying for it)."""
    assert routing_bench.ROUTING_ROW_KEYS == frozenset(ROUTING_ROW_KEYS)
    assert routing_bench.ROUTING_EXTRA_KEYS == frozenset(ROUTING_EXTRA_KEYS)
    row = dict.fromkeys(ROUTING_ROW_KEYS, 1)
    row.update(figure="routing", family="rrg", pattern="permutation",
               ecmp_gap_pct=34.7, ksp_gap_pct=5.1)
    extra = {"compile_keys": [[16, 6]], "last_plan": None, "k": 8,
             "iters": 400, "round2_new_compiles": {"routing.ksp_batch": 0}}
    path = write_bench_json("routing", [row], headline="h", wall_s=0.1,
                            extra=extra, out_dir=str(tmp_path))
    with open(path) as f:
        payload = json.load(f)
    assert path.endswith("BENCH_routing.json")
    assert set(payload) == PAYLOAD_KEYS | ROUTING_EXTRA_KEYS
    assert set(payload["rows"][0]) == ROUTING_ROW_KEYS
    assert payload["round2_new_compiles"] == {"routing.ksp_batch": 0}


def test_lifecycle_artifact_schema(tmp_path):
    """BENCH_lifecycle.json: row keys (certified degradation-curve points
    with ``reachable_mean``), the extra block (plan accounting + the
    expansion trajectory), and the per-step keys inside it — pinned here
    AND asserted at generation inside ``bench`` (CI's ``lifecycle_bench
    --smoke`` runs the real thing)."""
    assert lifecycle_bench.LIFECYCLE_ROW_KEYS == LIFECYCLE_ROW_KEYS
    assert lifecycle_bench.LIFECYCLE_EXTRA_KEYS == LIFECYCLE_EXTRA_KEYS
    assert lifecycle_bench.EXPANSION_STEP_KEYS == EXPANSION_STEP_KEYS
    row = dict.fromkeys(LIFECYCLE_ROW_KEYS, 1.0)
    row.update(figure="lifecycle", family="rrg", kind="links")
    step = dict.fromkeys(EXPANSION_STEP_KEYS, 0)
    step.update(lb_source="measured", chose="attached")
    extra = {"compile_keys": [[24, 24], [10, 12]], "executes": 3,
             "refills": 2, "last_plan": None,
             "expansion": {"steps": [step], "max_recabled_links": 2,
                           "growth_gain_pct": 1.5, "executes": 8,
                           "compile_keys": [[8, 2]]}}
    path = write_bench_json("lifecycle", [row], headline="h", wall_s=0.1,
                            extra=extra, out_dir=str(tmp_path))
    with open(path) as f:
        payload = json.load(f)
    assert path.endswith("BENCH_lifecycle.json")
    assert set(payload) == PAYLOAD_KEYS | LIFECYCLE_EXTRA_KEYS
    assert set(payload["rows"][0]) == LIFECYCLE_ROW_KEYS
    assert all(set(s) == EXPANSION_STEP_KEYS
               for s in payload["expansion"]["steps"])


def test_scale_artifact_schema(tmp_path):
    """BENCH_scale.json: uniform row schema across the frontier / coarsen
    / aot sections plus the scale extra block — pinned here AND asserted
    at generation inside ``bench`` (CI's ``scale_bench --smoke`` runs the
    real thing)."""
    assert scale_bench.SCALE_ROW_KEYS == SCALE_ROW_KEYS
    assert scale_bench.SCALE_EXTRA_KEYS == SCALE_EXTRA_KEYS
    row = dict.fromkeys(scale_bench._ROW_ORDER)
    row.update(figure="scale", section="frontier", backend="ell-bf",
               label="apsp-16384", n=16384, ok=True, wall_s=60.0,
               mem_gb=1.34, peak_rss_mb=1340.0, d_max=16, rounds=4)
    extra = {"mem_budget_gb": 1.5, "time_budget_s": 150.0,
             "frontier": {"squaring": 512, "blocked-fw": 4096,
                          "ell-bf": 16384},
             "coarsen_equal": True, "warm_over_cold": 0.1,
             "last_plan": None}
    path = write_bench_json("scale", [row], headline="h", wall_s=0.1,
                            extra=extra, out_dir=str(tmp_path))
    with open(path) as f:
        payload = json.load(f)
    assert path.endswith("BENCH_scale.json")
    assert set(payload) == PAYLOAD_KEYS | SCALE_EXTRA_KEYS
    assert set(payload["rows"][0]) == SCALE_ROW_KEYS
    assert payload["frontier"]["blocked-fw"] == 4096
    assert payload["frontier"]["ell-bf"] == 16384


def test_rows_with_numpy_scalars_stay_json_able(tmp_path):
    rows = [{"figure": "probe", "n": np.int64(16),
             "throughput": np.float32(0.5), "gap": np.float64(0.01)}]
    _, payload = _write(tmp_path, rows)
    assert payload["rows"][0]["n"] == 16
    assert payload["rows"][0]["throughput"] == pytest.approx(0.5)
