"""Unified engine API: registry, ThroughputResult agreement, batching, and
Topology as the single generator currency."""
import numpy as np
import pytest

from repro.core import (Topology, engine as engine_mod, fabric, get_engine,
                        graphs, heterogeneous as het, run_sweep, traffic, vl2)
from repro.core.engine import DualEngine, ExactLPEngine, Sweep


def _instance(n=16, r=4, servers=3, seed=0):
    topo = graphs.random_regular_graph(n, r, seed, servers=servers)
    dem = traffic.make("permutation", topo.servers, seed + 1)
    return topo, dem


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(engine_mod.ENGINES))
def test_get_engine_round_trips_every_name(name):
    # the adversarial engine runs a multi-round worst-TM search per solve;
    # a tiny budget keeps the registry round-trip cheap
    kw = ({"rounds": 1, "candidates": 2, "iters": 100}
          if name == "adversarial" else {})
    eng = get_engine(name, **kw)
    assert eng.name == name
    assert isinstance(eng, engine_mod.ThroughputEngine)
    topo, dem = _instance()
    res = eng.solve(topo, dem)
    assert isinstance(res, engine_mod.ThroughputResult)
    assert res.throughput > 0


def test_get_engine_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("simplex")


def test_as_engine_passes_instances_through():
    eng = DualEngine(iters=100)
    assert engine_mod.as_engine(eng) is eng
    assert isinstance(engine_mod.as_engine("exact"), ExactLPEngine)


def test_traffic_registry():
    servers = np.full(8, 4)
    topo = graphs.random_regular_graph(8, 3, seed=0, servers=4)
    for name in traffic.PATTERNS:
        # adversarial is the one pattern bound to a topology; give it the
        # wiring it attacks plus a tiny search budget
        kw = ({"topo": topo, "rounds": 1, "candidates": 2, "iters": 80}
              if name == "adversarial" else {})
        dem = traffic.make(name, servers, seed=3, **kw)
        assert dem.shape == (8, 8) and dem.sum() > 0
    assert traffic.make("stride", servers, 0, frac=0.5).sum() > 0
    with pytest.raises(ValueError, match="unknown traffic pattern"):
        traffic.make("gravity", servers, 0)


# ---------------------------------------------------------------------------
# result agreement + batching
# ---------------------------------------------------------------------------

def test_exact_and_dual_agree_on_paper_scale_rrg():
    topo, dem = _instance(n=40, r=10, servers=5, seed=2)
    exact = get_engine("exact").solve(topo, dem)
    dual = get_engine("dual").solve(topo, dem)
    assert not exact.is_upper_bound and dual.is_upper_bound
    assert dual.throughput >= exact.throughput - 1e-4
    assert dual.throughput == pytest.approx(exact.throughput, rel=0.02)


def test_dual_solve_batch_matches_per_instance_solve():
    eng = DualEngine(iters=300)
    # mixed sizes exercise the bucketed padded-batching path (12 and 16
    # both land in the 16-node pow2 bucket: one compiled program)
    insts = [_instance(12, 4, seed=s) for s in range(2)] + \
            [_instance(16, 4, seed=s) for s in range(2)]
    batch = eng.solve_batch([t for t, _ in insts], [d for _, d in insts])
    assert {r.meta["bucket"] for r in batch} == {16}
    for (topo, dem), got in zip(insts, batch):
        single = eng.solve(topo, dem)
        assert got.throughput == pytest.approx(single.throughput, rel=1e-4)
        assert got.engine == "dual" and got.is_upper_bound
        assert got.meta["iterations"] == single.meta["iterations"] == 300
        assert got.meta["final_ratio"] == pytest.approx(
            single.meta["final_ratio"], rel=1e-3)


def test_exact_solve_batch_matches_per_instance_solve():
    eng = ExactLPEngine()
    insts = [_instance(12, 4, seed=s) for s in range(3)]
    batch = eng.solve_batch([t for t, _ in insts], [d for _, d in insts])
    for (topo, dem), got in zip(insts, batch):
        assert got.throughput == pytest.approx(
            eng.solve(topo, dem).throughput, rel=1e-9)


# ---------------------------------------------------------------------------
# declarative sweeps
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_run_sweep_matches_manual_loop():
    spec = het.TwoClassSpec(6, 12, 12, 6, 48)
    sweep = Sweep(xs=(0.5, 1.0), runs=2, seed0=3)

    def build(x, seed):
        return het.build_two_class(spec, spec.proportional_large_servers,
                                   x, seed)

    pts = run_sweep(sweep, build, engine="exact")
    assert [p.x for p in pts] == [0.5, 1.0]
    eng = get_engine("exact")
    for p in pts:
        manual = []
        for seed in sweep.seeds():
            topo = build(p.x, seed)
            dem = traffic.make("permutation", topo.servers, seed + 1)
            manual.append(eng.solve(topo, dem).throughput)
        assert p.values == pytest.approx(manual)
        assert p.mean == pytest.approx(np.mean(manual))


@pytest.mark.slow
def test_run_sweep_dual_uses_one_batched_call(monkeypatch):
    calls = []
    orig = DualEngine.solve_batch

    def spy(self, topos, dems):
        calls.append(len(topos))
        return orig(self, topos, dems)

    monkeypatch.setattr(DualEngine, "solve_batch", spy)
    spec = het.TwoClassSpec(6, 12, 12, 6, 48)
    het.cross_cluster_sweep(spec, [0.5, 1.0, 1.5], runs=2,
                            engine=DualEngine(iters=60))
    assert calls == [6], "all (point x run) instances in one solve_batch"


def test_run_sweep_empty_xs_returns_empty():
    assert engine_mod.run_sweep(
        Sweep(xs=()), lambda x, s: graphs.random_regular_graph(8, 3, s),
        engine="exact") == []


@pytest.mark.slow
def test_run_sweeps_matches_individual_run_sweep():
    spec = het.TwoClassSpec(6, 12, 12, 6, 48)
    items = [het.cross_cluster_sweep_item(spec, [0.5, 1.0], runs=2, seed0=3),
             het.cross_cluster_sweep_item(spec, [1.5], runs=2, seed0=9)]
    family = engine_mod.run_sweeps(items, engine="exact")
    assert len(family) == 2
    for item, pts in zip(items, family):
        solo = engine_mod.run_sweep(*item, engine="exact")
        assert [p.x for p in pts] == [p.x for p in solo]
        for a, b in zip(pts, solo):
            assert a.values == pytest.approx(b.values)


@pytest.mark.slow
def test_whole_figure_family_uses_one_batched_call(monkeypatch):
    calls = []
    orig = DualEngine.solve_batch

    def spy(self, topos, dems):
        calls.append(len(topos))
        return orig(self, topos, dems)

    monkeypatch.setattr(DualEngine, "solve_batch", spy)
    spec = het.TwoClassSpec(6, 12, 12, 6, 48)
    # Fig. 6-style grid: 2 splits x 2 biases x 2 runs -> ONE planner pass
    out = het.combined_sweep(spec, [(4, 2), (2, 3)], [0.5, 1.0], runs=2,
                             engine=DualEngine(iters=60))
    assert calls == [8], "whole grid in one solve_batch/BatchPlan"
    assert sorted(out) == [(2, 3), (4, 2)]
    calls.clear()
    # Fig. 7(b)-style line-speed family: 2 speeds x 2 biases x 2 runs
    sp = het.TwoClassSpec(6, 12, 12, 6, 48, h_links=2, h_speed=4.0)
    het.line_speed_sweep(sp, [0.5, 1.0], h_speeds=[1.0, 4.0], runs=2,
                         engine=DualEngine(iters=60))
    assert calls == [8]


def test_throughput_shim_still_works():
    topo, dem = _instance()
    exact = het.throughput(topo, dem, engine="exact")
    assert exact == pytest.approx(
        get_engine("exact").solve(topo, dem).throughput)
    assert het.throughput(topo.cap, dem) == pytest.approx(exact)


# ---------------------------------------------------------------------------
# Topology as the single currency
# ---------------------------------------------------------------------------

def test_every_generator_returns_valid_topology():
    spec = het.TwoClassSpec(6, 12, 12, 6, 48)
    vspec = vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=5)
    topos = {
        "rrg": graphs.random_regular_graph(12, 4, 0, servers=2),
        "degrees": graphs.random_graph_from_degrees([4] * 10, 0, servers=1),
        "two_cluster": graphs.biased_two_cluster_graph([6] * 8, [4] * 8,
                                                       1.0, 0),
        "two_class": het.build_two_class(
            spec, spec.proportional_large_servers, 1.0, 0),
        "vl2": vl2.vl2_topology(vspec),
        "rewired_vl2": vl2.rewired_vl2_topology(vspec, vspec.n_tor_full, 0),
        "fabric": fabric.design_fabric([16] * 6, num_pods=8, seed=1).topology,
    }
    for name, topo in topos.items():
        assert isinstance(topo, Topology), name
        topo.validate()


def test_topology_is_array_like():
    topo = graphs.random_regular_graph(10, 3, 0)
    assert np.asarray(topo).shape == (10, 10)
    stacked = np.stack([topo, topo])
    assert stacked.shape == (2, 10, 10)
    np.testing.assert_array_equal(stacked[0], topo.cap)
