"""Server-expansion (``with_server_nodes``) and its exact inverse
(``Topology.coarsen``): round trips, bit-equal demand lifting, bit-equal
engine brackets, and the LP exactness argument behind ToR-coarsened plan
lanes."""
import numpy as np
import pytest

from repro.core import traffic
from repro.core.engine import ExactLPEngine, get_engine
from repro.core.graphs import Topology, random_regular_graph
from repro.core.vl2 import VL2Spec, vl2_topology


def _topo():
    return random_regular_graph(12, 4, seed=0, servers=3)


# ---------------------------------------------------------------------------
# representation round trip
# ---------------------------------------------------------------------------

def test_expand_coarsen_round_trip():
    t = _topo()
    ex = t.with_server_nodes()
    assert ex.n == t.n + t.num_servers
    assert int(ex.server_nodes.sum()) == t.num_servers
    assert ex.num_servers == t.num_servers       # one server per leaf node
    back = ex.coarsen()
    assert np.array_equal(back.cap, t.cap)
    assert np.array_equal(back.servers, t.servers)
    assert back.server_nodes is None


def test_expand_labels_follow_owners():
    spec = VL2Spec(d_a=4, d_i=4, servers_per_tor=2)
    ex = vl2_topology(spec, server_nodes=True)
    leaves = np.flatnonzero(ex.server_nodes)
    assert np.all(ex.labels[leaves] == 0), "servers inherit the ToR label"
    assert np.array_equal(ex.coarsen().cap, vl2_topology(spec).cap)


def test_expand_twice_rejected():
    ex = _topo().with_server_nodes()
    with pytest.raises(ValueError, match="already server-expanded"):
        ex.with_server_nodes()


def test_coarsen_rejects_non_leaf_server_nodes():
    t = _topo()
    ex = t.with_server_nodes()
    cap = ex.cap.copy()
    leaves = np.flatnonzero(ex.server_nodes)
    cap[leaves[0], leaves[1]] = cap[leaves[1], leaves[0]] = 1.0
    bad = Topology(cap=cap, servers=ex.servers, labels=ex.labels,
                   server_nodes=ex.server_nodes)
    with pytest.raises(ValueError, match="not .*degree-1|degree-1"):
        bad.coarsen()


def test_degrade_keeps_server_mask():
    ex = _topo().with_server_nodes()
    deg = ex.degrade(dead_switches=[0])
    assert np.array_equal(deg.server_nodes, ex.server_nodes)


# ---------------------------------------------------------------------------
# demand lifting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", ["permutation", "all_to_all",
                                     "all_to_one"])
def test_lifted_demand_bit_equals_switch_level_traffic(pattern):
    """A node-granular pattern over the expanded servers vector lifts to
    EXACTLY the switch-level pattern (same enumeration order, intra-switch
    pairs dropped on both sides)."""
    t = _topo()
    ex = t.with_server_nodes()
    d_sw = traffic.make(pattern, t.servers, seed=5)
    d_node = traffic.make(pattern, ex.servers, seed=5)
    _, lifted = ex.coarsen(d_node)
    assert np.array_equal(lifted, d_sw)


def test_lift_validates_demand_shape():
    ex = _topo().with_server_nodes()
    with pytest.raises(ValueError, match="demand shape"):
        ex.coarsen(np.zeros((3, 3)))


# ---------------------------------------------------------------------------
# engine integration: coarsened lanes, bit-equal brackets
# ---------------------------------------------------------------------------

def test_certified_brackets_bit_equal_and_lanes_smaller():
    t = _topo()
    ex = t.with_server_nodes()
    d_sw = traffic.make("permutation", t.servers, seed=3)
    d_node = traffic.make("permutation", ex.servers, seed=3)
    eng = get_engine("certified", iters=60)
    out = eng.solve_batch([t, ex], [d_sw, d_node])
    assert out[0].throughput == out[1].throughput
    assert out[0].meta["lb"] == out[1].meta["lb"]
    assert out[0].meta["ub"] == out[1].meta["ub"]
    # the coarsened lane is planned at switch size, not node size
    assert out[1].meta["nodes"] == t.n
    assert out[1].meta["padded_n"] < ex.n
    r1, r2 = eng.solve(t, d_sw), eng.solve(ex, d_node)
    assert (r1.throughput, r1.meta["ub"]) == (r2.throughput, r2.meta["ub"])


def test_coarsen_opt_out_solves_expanded_graph():
    t = _topo()
    ex = t.with_server_nodes()
    d_node = traffic.make("permutation", ex.servers, seed=3)
    eng = get_engine("dual", iters=60, coarsen=False)
    res = eng.solve_batch([ex], [d_node])[0]
    assert res.meta["nodes"] == ex.n, "opt-out keeps server-level lanes"


def test_lp_exactness_with_ample_nic_capacity():
    """θ* of the server-expanded network equals θ* of the coarsened one
    whenever NIC links never bind — the exactness argument for coarsening
    (fabric 10x the per-server demand here)."""
    t = random_regular_graph(8, 3, seed=2, servers=2)
    ex = t.with_server_nodes(nic_capacity=10.0)
    d_node = traffic.make("permutation", ex.servers, seed=1)
    _, d_sw = ex.coarsen(d_node)
    lp = ExactLPEngine()
    full = lp.solve(ex, d_node).throughput
    coarse = lp.solve(t, d_sw).throughput
    assert full == pytest.approx(coarse, rel=1e-6)
