"""Lifecycle tests: degraded-topology invariants, graceful-degradation
solver semantics (``on_disconnected`` across engines), seeded fleet
determinism, the one-execute-per-failure-kind plan contract, and the
expansion planner's equipment/budget/monotonicity guarantees."""
import numpy as np
import pytest

from repro.core import mcf, vl2
from repro.core.engine import CertifiedEngine, DualEngine, PrimalEngine
from repro.core.graphs import (Topology, biased_two_cluster_graph,
                               connected_components, random_regular_graph)
from repro.design.moves import swap_edges
from repro.design.spaces import Candidate
from repro.lifecycle import (ExpansionSpace, attach_new_switches,
                             degradation_surface, fail_links, fail_srg,
                             fail_switches, plan_expansion, recabled_links,
                             scenario_fleet, srg_from_labels)

BASE = random_regular_graph(16, 4, seed=0, servers=3)
VSPEC = vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=4)


def _split_mask(topo, group):
    """Link mask that cuts every link between ``group`` and the rest."""
    inside = np.zeros(topo.n, bool)
    inside[list(group)] = True
    return ~(inside[:, None] ^ inside[None, :])


# --- Topology.degrade -------------------------------------------------------

def test_degrade_link_mask_cuts_and_strands():
    mask = np.ones((BASE.n, BASE.n), bool)
    mask[0, :] = mask[:, 0] = False      # node 0 loses every link
    d = BASE.degrade(link_mask=mask)
    d.validate()
    assert d.n == BASE.n, "node count must never change"
    assert np.all(d.cap[0] == 0) and np.all(d.cap[:, 0] == 0)
    assert d.servers[0] == 0, "stranded servers must be zeroed"
    assert np.all(d.servers[1:] == BASE.servers[1:])
    assert BASE.servers[0] == 3, "degrade must not mutate the original"


def test_degrade_dead_switches():
    d = BASE.degrade(dead_switches=[2, 5])
    d.validate()
    assert np.all(d.cap[[2, 5], :] == 0) and np.all(d.cap[:, [2, 5]] == 0)
    assert d.servers[2] == d.servers[5] == 0
    surv = np.setdiff1d(np.arange(BASE.n), [2, 5])
    assert np.all(d.cap[np.ix_(surv, surv)] == BASE.cap[np.ix_(surv, surv)])


def test_degrade_everything_still_validates():
    d = BASE.degrade(dead_switches=np.arange(BASE.n))
    d.validate()
    assert d.cap.sum() == 0 and d.servers.sum() == 0 and d.n == BASE.n


def test_degrade_rejects_bad_inputs():
    bad = np.ones((BASE.n, BASE.n), bool)
    bad[0, 1] = False                     # asymmetric: 1->0 still True
    with pytest.raises(ValueError, match="symmetric"):
        BASE.degrade(link_mask=bad)
    with pytest.raises(ValueError, match="shape"):
        BASE.degrade(link_mask=np.ones((4, 4), bool))
    with pytest.raises(ValueError, match="out of range"):
        BASE.degrade(dead_switches=[BASE.n])
    with pytest.raises(ValueError, match="out of range"):
        BASE.degrade(dead_switches=[-1])


# --- graceful degradation in the solvers ------------------------------------

def test_aspl_on_disconnected_policies():
    two = BASE.degrade(link_mask=_split_mask(BASE, range(8)))
    assert len(np.unique(connected_components(two))) >= 2
    dem = np.ones((16, 16)) - np.eye(16)
    with pytest.raises(ValueError, match="disconnected"):
        mcf.aspl(two.cap, dem)
    a = mcf.aspl(two.cap, dem, on_disconnected="drop")
    assert np.isfinite(a) and a >= 1.0
    # unweighted ASPL always excludes disconnected pairs (no demand to
    # drop), so it stays finite either way
    assert np.isfinite(mcf.aspl(two.cap))
    with pytest.raises(ValueError, match="on_disconnected"):
        mcf.aspl(two.cap, dem, on_disconnected="ignore")
    # nothing routable at all: drop returns 0.0, never inf/nan
    zero_dem = np.ones((4, 4)) - np.eye(4)
    assert mcf.aspl(np.zeros((4, 4)), zero_dem,
                    on_disconnected="drop") == 0.0


def test_drop_disconnected_fraction_matches_components():
    two = BASE.degrade(link_mask=_split_mask(BASE, range(8)))
    dem = np.ones((16, 16)) - np.eye(16)
    kept, frac = mcf.drop_disconnected(two.cap, dem)
    # 2x (8 x 8) cross-blocks of the 240 off-diagonal pairs are dropped
    assert frac == pytest.approx(128 / 240)
    assert kept.sum() == pytest.approx(dem.sum() * (1 - frac))
    labels = connected_components(two.cap)
    assert np.all(kept[labels[:, None] != labels[None, :]] == 0)


@pytest.mark.parametrize("engine_cls",
                         [DualEngine, PrimalEngine, CertifiedEngine])
def test_engine_on_disconnected_raise_and_drop(engine_cls):
    two = BASE.degrade(link_mask=_split_mask(BASE, range(8)))
    dem = np.ones((16, 16)) - np.eye(16)
    with pytest.raises(ValueError, match="disconnected"):
        engine_cls(iters=8, on_disconnected="raise").solve(two, dem)
    eng = engine_cls(iters=8, on_disconnected="drop")
    r = eng.solve(two, dem)
    assert r.meta["dropped_demand_fraction"] == pytest.approx(128 / 240)
    assert np.isfinite(r.throughput) and r.throughput > 0
    # an intact instance under "drop" reports a zero dropped share
    r0 = eng.solve(BASE, dem)
    assert r0.meta["dropped_demand_fraction"] == 0.0
    with pytest.raises(ValueError, match="on_disconnected"):
        engine_cls(on_disconnected="ignore")


@pytest.mark.parametrize("engine_cls",
                         [DualEngine, PrimalEngine, CertifiedEngine])
def test_engine_drop_batch_handles_fully_dead_instances(engine_cls):
    dead = BASE.degrade(dead_switches=np.arange(BASE.n))
    two = BASE.degrade(link_mask=_split_mask(BASE, range(8)))
    dem = np.ones((16, 16)) - np.eye(16)
    eng = engine_cls(iters=8, on_disconnected="drop")
    rs = eng.solve_batch([BASE, dead, two], [dem, dem, dem])
    assert len(rs) == 3
    assert rs[1].throughput == 0.0 and rs[1].meta["disconnected"]
    assert rs[1].meta["dropped_demand_fraction"] == 1.0
    if engine_cls is CertifiedEngine:
        assert rs[1].meta["lb"] == rs[1].meta["ub"] == 0.0
    assert rs[0].meta["dropped_demand_fraction"] == 0.0
    assert rs[2].meta["dropped_demand_fraction"] > 0
    assert all(np.isfinite(r.throughput) for r in rs)
    # only the two live instances reached the planner
    assert eng.last_plan.instances == 2


# --- failure fleets ---------------------------------------------------------

def test_scenario_fleet_is_deterministic():
    a = scenario_fleet(BASE, "links", [0.1, 0.3], trials=3, seed=7)
    b = scenario_fleet(BASE, "links", [0.1, 0.3], trials=3, seed=7)
    assert len(a) == len(b) == 6
    for sa, sb in zip(a, b):
        assert np.array_equal(sa.topo.cap, sb.topo.cap)
        assert sa.failed_links == sb.failed_links
        assert sa.dead_switches == sb.dead_switches
    c = scenario_fleet(BASE, "links", [0.1, 0.3], trials=3, seed=8)
    assert any(not np.array_equal(sa.topo.cap, sc.topo.cap)
               for sa, sc in zip(a, c))


def test_fail_links_counts_and_shape():
    n_links = int((np.triu(BASE.cap, 1) > 0).sum())
    sc = fail_links(BASE, 0.25, np.random.default_rng(0))
    assert sc.failed_links == round(0.25 * n_links)
    assert sc.topo.n == BASE.n
    remaining = int((np.triu(sc.topo.cap, 1) > 0).sum())
    assert remaining == n_links - sc.failed_links
    sc.topo.validate()


def test_fail_switches_strands_servers():
    sc = fail_switches(BASE, 0.25, np.random.default_rng(1))
    assert len(sc.dead_switches) == 4
    assert sc.server_fraction <= 12 / 16   # at least the dead hosts' share
    for d in sc.dead_switches:
        assert np.all(sc.topo.cap[d] == 0) and sc.topo.servers[d] == 0


def test_fail_srg_kills_whole_label_classes():
    topo = vl2.vl2_topology(VSPEC)
    groups = srg_from_labels(topo)
    assert len(groups) == 3                     # ToR / agg / core layers
    sc = fail_srg(topo, 0.34, np.random.default_rng(2))
    assert len(sc.dead_switches) > 0
    killed_labels = set(topo.labels[list(sc.dead_switches)])
    for lab in killed_labels:                   # correlated: whole classes
        members = np.flatnonzero(topo.labels == lab)
        assert set(members) <= set(sc.dead_switches)
    # unlabeled topologies degrade to singleton groups
    assert len(srg_from_labels(BASE)) == BASE.n


def test_failure_generators_reject_bad_inputs():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="fraction"):
        fail_links(BASE, 1.5, rng)
    with pytest.raises(ValueError, match="unknown failure kind"):
        scenario_fleet(BASE, "meteor", [0.1], trials=1)
    with pytest.raises(ValueError, match="trials"):
        scenario_fleet(BASE, "links", [0.1], trials=0)


# --- degradation surfaces ---------------------------------------------------

@pytest.fixture(scope="module")
def tiny_surface():
    fams = {"rrg": random_regular_graph(12, 3, seed=0, servers=2),
            "tc": biased_two_cluster_graph([3] * 6, [3] * 6, 1.0, seed=0,
                                           servers=2)}
    eng = CertifiedEngine(iters=15, tol=1e-3)
    return degradation_surface(fams, kinds=("links", "switches"),
                               fractions=(0.1, 0.4), trials=2,
                               engine=eng, seed=0)


def test_surface_one_execute_per_kind_shared_keys(tiny_surface):
    s = tiny_surface.stats
    assert s["executes"] == 2          # ONE BatchPlan.execute per kind
    assert s["refills"] == 1           # kind 2 refilled kind 1's plan
    assert len(s["compile_keys"]) == 1, \
        "same-shape piles across kinds must share one compile key"
    assert s["instances_per_execute"] == 2 * 2 * 2


def test_surface_points_and_brackets(tiny_surface):
    pts = tiny_surface.points
    assert len(pts) == 2 * 2 * 2       # families x kinds x fractions
    for p in pts:
        assert p.lb_q10 <= p.lb_med <= p.lb_q90
        assert 0.0 <= p.reachable_mean <= 1.0
        assert np.isfinite(p.ub_mean) and p.gap_max >= 0.0
    # deterministic: the same call reproduces the same surface
    fams = {"rrg": random_regular_graph(12, 3, seed=0, servers=2),
            "tc": biased_two_cluster_graph([3] * 6, [3] * 6, 1.0, seed=0,
                                           servers=2)}
    again = degradation_surface(fams, kinds=("links", "switches"),
                                fractions=(0.1, 0.4), trials=2,
                                engine=CertifiedEngine(iters=15, tol=1e-3),
                                seed=0)
    assert [(p.lb_med, p.reachable_mean) for p in again.points] == \
        [(p.lb_med, p.reachable_mean) for p in tiny_surface.points]


def test_surface_total_failure_is_certified_zero():
    fams = {"rrg": random_regular_graph(12, 3, seed=0, servers=2)}
    res = degradation_surface(fams, kinds=("switches",), fractions=(1.0,),
                              trials=2,
                              engine=CertifiedEngine(iters=15), seed=0)
    (p,) = res.points
    assert p.lb_med == p.ub_mean == 0.0 and p.gap_max == 0.0
    assert p.reachable_mean == 0.0 and p.dead_trials == 2
    assert np.isfinite(p.lb_q10) and np.isfinite(p.lb_q90)


def test_surface_rejects_non_certifying_engine():
    fams = {"rrg": BASE}
    with pytest.raises(ValueError, match="primal"):
        degradation_surface(fams, engine=DualEngine(iters=8), trials=1)


# --- expansion --------------------------------------------------------------

def test_attach_preserves_equipment_and_budget():
    att = attach_new_switches(BASE, [6, 4], seed=3, max_breaks=4)
    t = att.topo
    t.validate()
    assert t.n == BASE.n + 2
    assert att.broken_links <= 4
    # every ORIGINAL switch keeps its exact attached capacity (ports)
    assert np.allclose(t.cap[:16].sum(axis=1), BASE.cap.sum(axis=1))
    # new switches never exceed their port budget; two links per break
    new_cap = t.cap[16:].sum(axis=1)
    assert new_cap[0] <= 6 and new_cap[1] <= 4
    assert new_cap.sum() == 2 * att.broken_links
    assert att.spare_ports == 6 + 4 - 2 * att.broken_links
    assert recabled_links(BASE.cap, t.cap) == att.broken_links
    assert int(t.servers.sum()) == int(BASE.servers.sum())


def test_attach_label_contract():
    labeled = vl2.vl2_topology(VSPEC)
    with pytest.raises(ValueError, match="labels"):
        attach_new_switches(labeled, [4])          # labeled needs labels
    with pytest.raises(ValueError, match="labels"):
        attach_new_switches(BASE, [4], labels=[1])  # unlabeled takes none
    att = attach_new_switches(labeled, [4], labels=[2], seed=0)
    assert att.topo.labels[-1] == 2


def test_expansion_space_swaps_never_exceed_budget():
    # two new switches: added links span two distinct new endpoints, so
    # double-swaps exist (a single new switch admits none — every added
    # link shares it)
    att = attach_new_switches(BASE, [6, 6], seed=0, max_breaks=6)
    space = ExpansionSpace(att.topo, BASE.cap)
    start = recabled_links(BASE.cap, att.topo.cap)
    cand = Candidate(topo=att.topo)
    rng = np.random.default_rng(0)
    for _ in range(12):
        new = swap_edges(cand, rng, space, swaps=2)
        if new is None:
            break
        rec = recabled_links(BASE.cap, new.topo.cap)
        assert rec <= start, \
            "swaps restricted to added links can only shrink recabling"
        assert np.allclose(new.topo.cap.sum(1), cand.topo.cap.sum(1))
        cand = new
    assert not np.array_equal(cand.topo.cap, att.topo.cap), \
        "the budgeted space must still admit some rewiring"


@pytest.mark.slow
def test_plan_expansion_monotone_lb_and_budget():
    base = random_regular_graph(12, 3, seed=0, servers=2)
    res = plan_expansion(base, [[4], [4]], max_recabled_links=2,
                         engine=CertifiedEngine(iters=20, tol=1e-3),
                         rounds=1, fleet=3, elite=2, runs=2, seed=0)
    assert len(res.steps) == 3                  # start + 2 growth steps
    lbs = [s.lb for s in res.steps]
    assert all(b >= a for a, b in zip(lbs, lbs[1:])), lbs
    assert all(s.recabled <= 2 for s in res.steps)
    assert res.steps[0].recabled == 0
    assert [s.topo.n for s in res.steps] == [12, 13, 14]
    assert res.stats["lb_trajectory"] == tuple(lbs)
    # every grown wiring conserves the original switches' equipment
    for s in res.steps[1:]:
        assert np.allclose(s.topo.cap.sum(1)[:12], base.cap.sum(1))


def test_plan_expansion_vl2_respects_forbidden_pairs():
    spec = vl2.VL2Spec(d_a=4, d_i=2, servers_per_tor=4)
    start = vl2.rewired_vl2_topology(spec, n_tor=4, seed=0)

    def forbid(t):
        tor = t.labels == 0
        return tor[:, None] & tor[None, :]

    res = plan_expansion(start, [[4]], max_recabled_links=2,
                         engine=CertifiedEngine(iters=20, tol=1e-3),
                         new_labels=[2], forbidden_fn=forbid,
                         link_unit=vl2.FABRIC,
                         rounds=1, fleet=3, elite=2, runs=2, seed=0)
    final = res.steps[-1].topo
    assert final.labels[-1] == 2
    tor = final.labels == 0
    assert np.all(final.cap[np.ix_(tor, tor)] == 0), \
        "growth must never wire ToR-ToR"
    assert res.steps[-1].lb >= res.steps[0].lb
