"""Training-loop integration: loss decreases, grad-accum equivalence,
deterministic checkpoint-resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_batch
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.optim import AdamW, cosine_schedule

CFG = ModelConfig(name="ti", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                  head_dim=16, dtype="float32")


def _run(steps, accum, batch=8, seq=64, seed=0):
    model = model_lib.get_model(CFG)
    opt = AdamW(lr=cosine_schedule(3e-3, 5, steps))
    params = model.init_params(jax.random.PRNGKey(seed))
    state = opt.init(params)
    step_fn = jax.jit(model_lib.make_train_step(CFG, opt, accum=accum))
    losses = []
    for s in range(steps):
        b = make_batch(CFG, batch, seq, s, seed, accum=accum)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, state, m = step_fn(params, state, b)
        losses.append(float(m["loss"]))
    return params, losses


def test_loss_decreases():
    _, losses = _run(steps=30, accum=1)
    assert losses[-1] < losses[0] - 0.3, losses[:: max(len(losses) // 6, 1)]
    assert np.isfinite(losses).all()


def test_grad_accum_equivalent_to_large_batch():
    p1, l1 = _run(steps=3, accum=1, batch=8)
    p2, l2 = _run(steps=3, accum=4, batch=8)
    # same data, same effective batch -> same loss and params
    np.testing.assert_allclose(l1, l2, rtol=1e-4)
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-4


def test_train_driver_resume_bitwise(tmp_path):
    from repro.launch import train as train_mod
    d = str(tmp_path / "ck")
    args = ["--arch", "musicgen-medium", "--smoke", "--batch", "4",
            "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "4",
            "--log-every", "100"]
    out1 = train_mod.main(args + ["--steps", "8"])
    # restart from the step-8 checkpoint and run 4 more
    out2 = train_mod.main(args + ["--steps", "12", "--resume"])
    # fresh 12-step run must agree with checkpoint-resumed run exactly
    out3 = train_mod.main(["--arch", "musicgen-medium", "--smoke",
                           "--batch", "4", "--seq", "32",
                           "--log-every", "100", "--steps", "12"])
    assert out2["last_loss"] == pytest.approx(out3["last_loss"], abs=1e-5)


def test_serve_driver_greedy_deterministic():
    from repro.launch import serve as serve_mod
    cfg = CFG
    model = model_lib.get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, 64, (2, 12)).astype(np.int32)
    t1 = serve_mod.generate(cfg, params, prompts, gen=6)
    t2 = serve_mod.generate(cfg, params, prompts, gen=6)
    np.testing.assert_array_equal(t1, t2)
    assert t1.shape == (2, 18)
