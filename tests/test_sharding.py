"""Sharding rules: resolution logic + full coverage of every arch's param
tree + an 8-device SPMD integration test (subprocess, forced host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import model as model_lib
from repro.parallel import sharding as sh


class FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 4, "model": 4}
    size = 32


def test_resolve_divisible_and_drop():
    spec = sh._resolve((("pod", "data"), "model", None), (8, 12, 5),
                       FakeMesh(), uneven_ok=False)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"), "model", None)
    # non-divisible dims are dropped when uneven is not allowed
    spec = sh._resolve((("pod", "data"), "model", None), (7, 5, 5),
                       FakeMesh(), uneven_ok=False)
    assert spec == jax.sharding.PartitionSpec(None, None, None)
    # uneven allowed: keep if dim >= axis/2
    spec = sh._resolve((None, "model"), (3, 10), FakeMesh(), uneven_ok=True)
    assert spec == jax.sharding.PartitionSpec(None, "model")
    spec = sh._resolve((None, "model"), (3, 1), FakeMesh(), uneven_ok=True)
    assert spec == jax.sharding.PartitionSpec(None, None)


@pytest.mark.parametrize("arch", sorted(ARCH_IDS), ids=str)
def test_param_rules_cover_every_arch(arch):
    cfg = get_smoke(arch)
    model = model_lib.get_model(cfg)
    params = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    name_of = sh.make_param_rule(expert_parallel=False)
    rules = sh.ShardingRules.default().rules
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        rule, leading = name_of(path)
        assert rule in rules, (arch, path)
        template = rules[rule]
        assert len(leaf.shape) - leading <= len(template), (arch, path)


@pytest.mark.parametrize("arch", sorted(ARCH_IDS), ids=str)
def test_cache_rules_cover_every_arch(arch):
    cfg = get_smoke(arch)
    model = model_lib.get_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(2, 16))
    for path, _ in jax.tree_util.tree_flatten_with_path(cache)[0]:
        rule, _ = sh.cache_rule(path)
        assert rule is not None, (arch, path)


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke
    from repro.data import make_batch
    from repro.models import model as model_lib
    from repro.optim import AdamW
    from repro.parallel import sharding as sh

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = get_smoke("qwen2.5-14b")
    model = model_lib.get_model(cfg)
    shard = sh.make_shard_fn(mesh)
    opt = AdamW(lr=1e-3)
    step = model_lib.make_train_step(cfg, opt, shard, accum=2)

    params = model.init_params(jax.random.PRNGKey(0))
    state = opt.init(params)
    p_specs = sh.state_specs(jax.eval_shape(lambda: params), mesh, "param")
    o_specs = sh.state_specs(jax.eval_shape(lambda: state), mesh, "opt")
    params = jax.device_put(params, p_specs)
    state = jax.device_put(state, o_specs)

    b = make_batch(cfg, 8, 32, 0, accum=2)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    jstep = jax.jit(step, in_shardings=(p_specs, o_specs,
                                        jax.tree.map(lambda _: None, b)))
    params, state, m = jstep(params, state, b)
    sharded_loss = float(m["loss"])

    # reference: unsharded single-device run of the same step
    params0 = model.init_params(jax.random.PRNGKey(0))
    state0 = opt.init(params0)
    step0 = model_lib.make_train_step(cfg, opt, accum=2)
    _, _, m0 = jax.jit(step0)(params0, state0, b)
    print(json.dumps({"sharded": sharded_loss, "ref": float(m0["loss"])}))
""")


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="installed jax predates jax.sharding.AxisType")
def test_spmd_train_step_matches_unsharded():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["sharded"] == pytest.approx(res["ref"], rel=2e-2), res
