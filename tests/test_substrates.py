"""Data pipeline, optimizer, compression, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis import given, settings, st

from repro.checkpoint import (Checkpointer, latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.data import SyntheticLM, make_batch
from repro.models.config import ModelConfig
from repro.optim import AdamW, cosine_schedule, ef_compress_mean, \
    int8_dequantize, int8_quantize


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_shifted():
    pipe = SyntheticLM(vocab_size=101, seq_len=32, global_batch=8, seed=3)
    b1 = pipe.batch(step=5)
    b2 = pipe.batch(step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    assert not np.array_equal(pipe.batch(6)["tokens"], b1["tokens"])


def test_data_shards_partition_batch():
    pipe = SyntheticLM(vocab_size=50, seq_len=8, global_batch=12, seed=0)
    full = pipe.batch(step=2)
    parts = [pipe.batch(step=2, host_id=h, num_hosts=4) for h in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts]), full["tokens"])


def test_data_any_host_can_rebuild_any_shard():
    """straggler mitigation: shard content is host-independent."""
    pipe = SyntheticLM(vocab_size=50, seq_len=8, global_batch=12, seed=0)
    a = pipe.batch(step=7, host_id=2, num_hosts=4)
    idx = pipe.shard_indices(2, 4)
    rebuilt = np.stack([pipe.example(7, int(i)) for i in idx])
    np.testing.assert_array_equal(a["tokens"], rebuilt[:, :-1])


def test_make_batch_vlm_layout():
    cfg = ModelConfig(name="v", family="vlm", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      frontend="patch", frontend_dim=8, frontend_len=4,
                      mrope_sections=(2, 3, 3))
    b = make_batch(cfg, batch_size=4, seq_len=16, step=0, accum=2)
    assert b["tokens"].shape == (2, 2, 12)
    assert b["patch_embeds"].shape == (2, 2, 4, 8)
    assert b["labels"].shape == (2, 2, 16)
    assert np.all(b["labels"][:, :, :4] == -1)
    assert b["positions"].shape == (2, 2, 3, 16)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_clipping_limits_update():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    g = {"w": jnp.full((4,), 100.0)}
    assert float(opt.global_norm(g)) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100,
                         final_frac=0.1)
    assert float(lr(0)) == pytest.approx(0.1)
    assert float(lr(10)) == pytest.approx(1.0, abs=0.1)
    assert float(lr(100)) == pytest.approx(0.1, abs=0.02)


@settings(max_examples=10)
@given(st.integers(0, 999))
def test_int8_quantization_error_bound(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, s = int8_quantize(g)
    err = jnp.abs(int8_dequantize(q, s) - g).max()
    assert float(err) <= float(s) / 2 + 1e-6


def test_ef_compress_mean_is_unbiased_over_steps():
    """error feedback: accumulated compressed means converge to the true
    mean of the gradients (the residual stays bounded)."""
    npod = 2
    key = jax.random.PRNGKey(0)
    err = {"w": jnp.zeros((npod, 32), jnp.bfloat16)}
    total_true = jnp.zeros(32)
    total_comp = jnp.zeros(32)
    for step in range(20):
        g = jax.random.normal(jax.random.fold_in(key, step), (npod, 32))
        mean, err_new = ef_compress_mean({"w": g}, err, npod)
        err = {"w": err_new["w"]}
        total_true += g.mean(0)
        total_comp += mean["w"]
    resid = jnp.abs(total_true - total_comp).max()
    # residual equals the current EF buffer mean -> bounded, not growing
    assert float(resid) <= float(jnp.abs(err["w"].astype(jnp.float32)).max()) \
        + 1e-2


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state(x: float):
    return {"params": {"w": jnp.full((3, 2), x)},
            "opt": {"m": jnp.zeros((3, 2)), "step": jnp.int32(7)},
            "data_step": np.int64(13)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 5, _state(1.5))
    assert latest_step(d) == 5
    step, restored = restore_checkpoint(d, jax.tree.map(jnp.zeros_like,
                                                        _state(0.0)))
    assert step == 5
    np.testing.assert_array_equal(restored["params"]["w"],
                                  _state(1.5)["params"]["w"])
    assert int(restored["opt"]["step"]) == 7
    assert int(restored["data_step"]) == 13


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _state(1.0))
    bad = {"params": {"w": jnp.zeros((4, 2))},
           "opt": {"m": jnp.zeros((3, 2)), "step": jnp.int32(0)},
           "data_step": np.int64(0)}
    with pytest.raises(ValueError):
        restore_checkpoint(d, bad)


def test_checkpoint_retention_and_atomicity(tmp_path):
    d = str(tmp_path)
    ck = Checkpointer(d, every=1, keep=2)
    for s in range(1, 6):
        ck.maybe_save(s, _state(float(s)))
    names = sorted(os.listdir(d))
    assert names == ["step_00000004.npz", "step_00000005.npz"]
    # a stale tmp file (crashed write) is ignored and swept
    open(os.path.join(d, "junk.tmp"), "w").write("partial")
    assert latest_step(d) == 5
    ck.maybe_save(6, _state(6.0))
    assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_restore_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), _state(0.0))
