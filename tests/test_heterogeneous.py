"""Paper §5 experiment drivers reproduce the qualitative conclusions
(small-N versions; the full sweeps live in benchmarks/)."""
import numpy as np
import pytest

from repro.core import heterogeneous as het


SPEC = het.TwoClassSpec(n_large=8, k_large=16, n_small=16, k_small=8,
                        num_servers=96)


@pytest.mark.slow
def test_proportional_server_distribution_is_peak():
    pts = het.server_distribution_sweep(SPEC, [0.4, 1.0, 1.6], runs=3)
    by_x = {p.x: p.mean for p in pts}
    assert by_x[1.0] > by_x[0.4]
    assert by_x[1.0] > by_x[1.6]


@pytest.mark.slow
def test_cross_cluster_plateau_and_collapse():
    pts = het.cross_cluster_sweep(SPEC, [0.1, 0.8, 1.0, 1.4], runs=3)
    by_x = {p.x: p.mean for p in pts}
    # collapse when the cut is starved
    assert by_x[0.1] < 0.7 * by_x[1.0]
    # plateau: vanilla-random vs biased within a modest band
    assert abs(by_x[1.4] - by_x[1.0]) < 0.2 * by_x[1.0]
    assert abs(by_x[0.8] - by_x[1.0]) < 0.2 * by_x[1.0]


def test_power_law_beta_one_near_optimal():
    pts = het.power_law_beta_sweep(n=24, k_min=4, k_max=24, alpha=2.0,
                                   num_servers=60,
                                   betas=[0.0, 1.0, 2.0], runs=3)
    by_b = {p.x: p.mean for p in pts}
    assert by_b[1.0] >= by_b[0.0] * 0.98
    assert by_b[1.0] >= by_b[2.0] * 0.98


def test_combined_sweep_validates_splits():
    splits = [(9, 1.5)]
    with pytest.raises(ValueError):
        het.combined_sweep(SPEC, [(9, 2)], biases=[1.0], runs=1)


@pytest.mark.slow
def test_line_speed_more_capacity_helps_at_peak():
    spec = het.TwoClassSpec(n_large=8, k_large=16, n_small=16, k_small=8,
                            num_servers=96, h_links=2, h_speed=1.0)
    out = het.line_speed_sweep(spec, biases=[1.0], h_speeds=[1.0, 4.0],
                               runs=3)
    assert out[4.0][0].mean >= out[1.0][0].mean - 1e-6


def test_build_two_class_structure():
    topo = het.build_two_class(SPEC, SPEC.proportional_large_servers,
                               cross_bias=1.0, seed=0)
    topo.validate()
    assert topo.num_servers == SPEC.num_servers
    deg = (topo.cap > 0).sum(1) + topo.servers
    # every port is a server or a network link (minus parity fixups)
    ports = np.concatenate([np.full(8, 16), np.full(16, 8)])
    assert np.all(deg <= ports)
    assert deg.sum() >= ports.sum() - 4
