"""Cross-engine conformance corpus.

For every named traffic pattern x topology family, the three JAX solver
claims must mechanically agree with the exact LP oracle:

    primal lower bound  <=  ExactLPEngine theta  <=  dual upper bound

with a certified bracket gap (ub - lb) / ub below 5%.  This is what lets
sweeps beyond the LP's reach (n > 64, where ``AutoEngine`` cuts the exact
solver off) trust their throughput numbers: the same machinery that is
verified here at small scale produces the brackets at large scale.

All instances of the corpus are solved in ONE batched call per engine
(they share one BatchPlan bucket), so the module costs a single compile
per engine, not one per case.
"""
import pytest

from repro.core import get_engine, graphs, traffic, vl2

ITERS = 1000
MAX_GAP = 0.05

_VL2 = vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=5)

TOPOLOGIES = {
    "random_regular": lambda: graphs.random_regular_graph(
        16, 4, seed=0, servers=3),
    "biased_two_cluster": lambda: graphs.biased_two_cluster_graph(
        [6] * 8, [4] * 8, cross_bias=0.6, seed=1, servers=2),
    "vl2": lambda: vl2.vl2_topology(_VL2, n_tor=4),
}

CASES = [(t, p) for t in sorted(TOPOLOGIES) for p in sorted(traffic.PATTERNS)]
IDS = [f"{t}-{p}" for t, p in CASES]


@pytest.fixture(scope="module")
def corpus():
    """Solve the whole corpus once: exact per instance, primal / dual /
    certified each as one batched solve."""
    topos, dems = [], []
    for topo_name, pattern in CASES:
        topo = TOPOLOGIES[topo_name]()
        if pattern == "adversarial":
            # the worst-TM search needs the topology it attacks; a tiny
            # budget suffices — conformance only needs SOME hose-feasible
            # matrix out of the search, not a converged worst case
            dem = traffic.make(pattern, topo.servers, seed=11, topo=topo,
                               rounds=1, candidates=2, iters=150)
        else:
            dem = traffic.make(pattern, topo.servers, seed=11)
        assert dem.sum() > 0, f"{topo_name}-{pattern}: empty demand"
        topos.append(topo)
        dems.append(dem)
    exact = [get_engine("exact").solve(t, d).throughput
             for t, d in zip(topos, dems)]
    primal_eng = get_engine("primal", iters=ITERS)
    dual_eng = get_engine("dual", iters=ITERS)
    cert_eng = get_engine("certified", iters=ITERS)
    prim = primal_eng.solve_batch(topos, dems)
    dual = dual_eng.solve_batch(topos, dems)
    cert = cert_eng.solve_batch(topos, dems)
    # primal lanes must have ridden the same plan shapes as dual lanes
    assert primal_eng.last_plan.compile_keys == \
        dual_eng.last_plan.compile_keys
    return {case: {"exact": exact[i], "lb": prim[i].throughput,
                   "ub": dual[i].throughput, "certified": cert[i]}
            for i, case in enumerate(CASES)}


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_bracket_contains_exact_theta(case, corpus):
    r = corpus[case]
    assert r["lb"] <= r["exact"] * (1 + 1e-3), \
        f"primal lb {r['lb']} exceeds exact {r['exact']}"
    assert r["exact"] <= r["ub"] * (1 + 1e-3), \
        f"dual ub {r['ub']} below exact {r['exact']}"


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_bracket_gap_under_five_percent(case, corpus):
    r = corpus[case]
    gap = (r["ub"] - r["lb"]) / r["ub"]
    assert gap < MAX_GAP, f"bracket gap {gap:.3f} >= {MAX_GAP}"


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_certified_engine_meta_gap(case, corpus):
    """Acceptance: get_engine("certified") brackets close to <= 5% on the
    corpus, and the bracket is consistent with the standalone engines."""
    r = corpus[case]
    c = corpus[case]["certified"]
    assert c.meta["gap"] <= MAX_GAP
    assert c.meta["lb"] <= r["exact"] * (1 + 1e-3) <= \
        c.meta["ub"] * (1 + 2e-3)
    # the fused ub is the same dual descent the dual engine runs
    assert c.meta["ub"] == pytest.approx(r["ub"], rel=5e-3)
    assert c.meta["lb"] == pytest.approx(r["lb"], rel=5e-3)


def test_corpus_spans_the_registry():
    """The corpus parametrization stays in sync with traffic.PATTERNS, so
    a new pattern is automatically conformance-tested."""
    patterns = {p for _, p in CASES}
    assert patterns == set(traffic.PATTERNS)
    assert {t for t, _ in CASES} == set(TOPOLOGIES)
