"""Cross-engine conformance corpus.

For every named traffic pattern x topology family, the solver claims
must mechanically agree with the exact LP oracle.  The ideal engines
bracket it:

    primal lower bound  <=  ExactLPEngine theta  <=  dual upper bound

with a certified bracket gap (ub - lb) / ub below 5%; and the
routing-restricted engines order below it (the routing lattice):

    ecmp  <=  ksp(k)  <=  exact theta  <=  dual upper bound

This is what lets sweeps beyond the LP's reach (n > 64, where
``AutoEngine`` cuts the exact solver off) trust their throughput
numbers: the same machinery that is verified here at small scale
produces the brackets at large scale.  A separate k-ladder test checks
ksp is monotone in k and converges to the ideal optimum at large k,
cross-checked against a scipy path-restricted LP.

All instances of the corpus are solved in ONE batched call per engine
(they share one BatchPlan bucket), so the module costs a single compile
per engine, not one per case.
"""
import pytest

from repro.core import get_engine, graphs, routing, traffic, vl2
from repro.kernels import paths as kpaths

ITERS = 1000
# the routing lower-bound programs need no 1000-iter descent for the
# lattice to hold (ECMP is a single fixed-point evaluation; the MW
# program's certificate is valid at every iterate) — a smaller budget
# keeps the module inside the tier-1 time budget
ROUTING_ITERS = 350
MAX_GAP = 0.05

_VL2 = vl2.VL2Spec(d_a=4, d_i=4, servers_per_tor=5)

TOPOLOGIES = {
    "random_regular": lambda: graphs.random_regular_graph(
        16, 4, seed=0, servers=3),
    "biased_two_cluster": lambda: graphs.biased_two_cluster_graph(
        [6] * 8, [4] * 8, cross_bias=0.6, seed=1, servers=2),
    "vl2": lambda: vl2.vl2_topology(_VL2, n_tor=4),
}

CASES = [(t, p) for t in sorted(TOPOLOGIES) for p in sorted(traffic.PATTERNS)]
IDS = [f"{t}-{p}" for t, p in CASES]


@pytest.fixture(scope="module")
def corpus():
    """Solve the whole corpus once: exact per instance, primal / dual /
    certified each as one batched solve."""
    topos, dems = [], []
    for topo_name, pattern in CASES:
        topo = TOPOLOGIES[topo_name]()
        if pattern == "adversarial":
            # the worst-TM search needs the topology it attacks; a tiny
            # budget suffices — conformance only needs SOME hose-feasible
            # matrix out of the search, not a converged worst case
            dem = traffic.make(pattern, topo.servers, seed=11, topo=topo,
                               rounds=1, candidates=2, iters=150)
        else:
            dem = traffic.make(pattern, topo.servers, seed=11)
        assert dem.sum() > 0, f"{topo_name}-{pattern}: empty demand"
        topos.append(topo)
        dems.append(dem)
    exact = [get_engine("exact").solve(t, d).throughput
             for t, d in zip(topos, dems)]
    primal_eng = get_engine("primal", iters=ITERS)
    dual_eng = get_engine("dual", iters=ITERS)
    cert_eng = get_engine("certified", iters=ITERS)
    prim = primal_eng.solve_batch(topos, dems)
    dual = dual_eng.solve_batch(topos, dems)
    cert = cert_eng.solve_batch(topos, dems)
    ecmp_eng = get_engine("ecmp", iters=ROUTING_ITERS)
    ksp_eng = get_engine("ksp", iters=ROUTING_ITERS, k=8)
    ecmp = ecmp_eng.solve_batch(topos, dems)
    ksp = ksp_eng.solve_batch(topos, dems)
    # every engine's lanes must have ridden the same plan shapes
    for eng in (primal_eng, ecmp_eng, ksp_eng):
        assert eng.last_plan.compile_keys == \
            dual_eng.last_plan.compile_keys
    return {case: {"exact": exact[i], "lb": prim[i].throughput,
                   "ub": dual[i].throughput, "certified": cert[i],
                   "ecmp": ecmp[i], "ksp": ksp[i]}
            for i, case in enumerate(CASES)}


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_bracket_contains_exact_theta(case, corpus):
    r = corpus[case]
    assert r["lb"] <= r["exact"] * (1 + 1e-3), \
        f"primal lb {r['lb']} exceeds exact {r['exact']}"
    assert r["exact"] <= r["ub"] * (1 + 1e-3), \
        f"dual ub {r['ub']} below exact {r['exact']}"


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_bracket_gap_under_five_percent(case, corpus):
    r = corpus[case]
    gap = (r["ub"] - r["lb"]) / r["ub"]
    assert gap < MAX_GAP, f"bracket gap {gap:.3f} >= {MAX_GAP}"


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_certified_engine_meta_gap(case, corpus):
    """Acceptance: get_engine("certified") brackets close to <= 5% on the
    corpus, and the bracket is consistent with the standalone engines."""
    r = corpus[case]
    c = corpus[case]["certified"]
    assert c.meta["gap"] <= MAX_GAP
    assert c.meta["lb"] <= r["exact"] * (1 + 1e-3) <= \
        c.meta["ub"] * (1 + 2e-3)
    # the fused ub is the same dual descent the dual engine runs
    assert c.meta["ub"] == pytest.approx(r["ub"], rel=5e-3)
    assert c.meta["lb"] == pytest.approx(r["lb"], rel=5e-3)


@pytest.mark.parametrize("case", CASES, ids=IDS)
def test_routing_ordering_lattice(case, corpus):
    """The routing lattice on every pattern x family:
    ecmp <= ksp(8) <= exact <= dual ub.  The first inequality is
    guaranteed by construction (the KSP program floors its bound with
    the ECMP operating point); the second holds because both are
    certified feasible routings of the unrestricted problem."""
    r = corpus[case]
    e, k = r["ecmp"], r["ksp"]
    assert e.bound == "lower" and k.bound == "lower"
    assert e.throughput <= k.throughput * (1 + 1e-5), \
        f"ecmp {e.throughput} above ksp {k.throughput}"
    assert k.throughput <= r["exact"] * (1 + 2e-3), \
        f"ksp {k.throughput} above exact {r['exact']}"
    assert r["exact"] <= r["ub"] * (1 + 1e-3)
    # the fused ideal ub rides along in meta as a percentage gap
    assert e.meta["ideal_gap_pct"] >= -1e-3
    assert k.meta["ideal_gap_pct"] >= -1e-3
    assert k.meta["ideal_gap_pct"] <= e.meta["ideal_gap_pct"] + 1e-3


def test_ksp_monotone_in_k_and_matches_exact():
    """The k-ladder: ksp throughput is non-decreasing in k (up to the
    first-order solver's tolerance), reaches the ideal optimum within 2%
    at large k, and the exact optimum of the path restriction — scipy
    linprog over the same enumerated path sets — is itself monotone and
    converged, cross-checking the MW program against an independent LP."""
    topo = graphs.random_regular_graph(10, 3, seed=2, servers=2)
    dem = traffic.make("permutation", topo.servers, seed=3)
    exact = get_engine("exact").solve(topo, dem).throughput
    ks = (1, 2, 4, 8, 16)
    vals = [get_engine("ksp", k=k, iters=500).solve(topo, dem).throughput
            for k in ks]
    for lo, hi in zip(vals, vals[1:]):
        # monotone up to the fixed-iteration MW budget's wobble
        assert hi >= lo - 0.01 * exact, (ks, vals)
    assert vals[-1] >= 0.98 * exact, (exact, vals)     # within 2% at k=16
    assert vals[-1] <= exact * (1 + 2e-3)
    # independent oracle: exact LP over the same path sets
    cap = graphs.as_cap(topo)
    # engine preprocessing coarsens server topologies; here servers ride
    # on every switch so dem is already switch-shaped
    assert dem.shape == cap.shape
    lps = [routing.path_lp_throughput(
        cap, dem, kpaths.k_shortest_paths(cap, k=k, max_hops=9))
        for k in ks]
    for lo, hi in zip(lps, lps[1:]):
        assert hi >= lo - 1e-9, (ks, lps)   # certified optimum: monotone
    assert lps[-1] <= exact * (1 + 1e-6)    # restriction never beats ideal
    assert lps[-1] >= 0.98 * exact          # ... and converges by k=16
    assert vals[-1] <= lps[-1] * (1 + 2e-3)  # MW never beats its own LP


def test_corpus_spans_the_registry():
    """The corpus parametrization stays in sync with traffic.PATTERNS, so
    a new pattern is automatically conformance-tested."""
    patterns = {p for _, p in CASES}
    assert patterns == set(traffic.PATTERNS)
    assert {t for t, _ in CASES} == set(TOPOLOGIES)
