"""Property and contract tests for the routing-restricted engines.

Three layers:

* **Path enumeration properties** (hypothesis): every path emitted by
  ``repro.kernels.paths.k_shortest_paths`` is simple, starts/ends at its
  (s, t) pair, walks only real positive-capacity edges, and per-pair
  lengths are non-decreasing in k — on random regular and biased
  two-cluster graphs, on padded lanes, and on server-coarsened
  topologies.
* **Plan contracts**: ``get_engine("ecmp")`` / ``get_engine("ksp")``
  run a whole sweep through ONE ``BatchPlan`` (one plan spanning every
  instance per ``solve_batch``), and a ``refill`` round re-executes on
  the same compile keys with zero new routing-solver XLA compiles.
* **Sweep aggregation**: the ``run_sweeps`` ``meta_reduce`` hook
  aggregates engine-specific meta (``ideal_gap_pct``) into
  ``SweepPoint.meta`` without changing the existing ``lb_mean`` /
  ``gap_max`` bracket aggregation (regression for the silent meta-drop).

The ordering lattice itself (ecmp <= ksp <= exact <= dual) lives in
``tests/test_conformance.py`` with the rest of the cross-engine corpus.
"""
import numpy as np
import pytest

from repro.core import routing, traffic
from repro.core.engine import Sweep, get_engine, run_sweeps
from repro.core.graphs import (as_cap, biased_two_cluster_graph,
                               random_regular_graph)
from repro.core.plan import BatchPlan, compile_cache_sizes
from repro.kernels import paths as kpaths
from tests._hypothesis import given, settings, st
from tests._seedcheck import unseeded_rng_calls


def assert_path_properties(cap: np.ndarray, paths: np.ndarray,
                           k: int) -> None:
    """The four guarantees of ``k_shortest_paths`` for every pair."""
    n = cap.shape[0]
    for s in range(n):
        for t in range(n):
            lens = []
            for j in range(k):
                p = paths[s, t, j]
                real = p[p >= 0]
                if real.size == 0:
                    assert np.all(p == -1), (s, t, j, p)
                    continue
                assert np.all(p[:real.size] >= 0), ("pad gap", s, t, j, p)
                assert real[0] == s and real[-1] == t, (s, t, j, real)
                assert np.unique(real).size == real.size, \
                    ("not simple", s, t, j, real)
                assert np.all(cap[real[:-1], real[1:]] > 0), \
                    ("not an edge", s, t, j, real)
                lens.append(real.size - 1)
            assert lens == sorted(lens), \
                ("length not monotone in k", s, t, lens)
            if s == t:
                assert np.all(paths[s, t] == -1)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       half=st.integers(4, 8), d=st.sampled_from([3, 4]))
def test_paths_properties_random_regular(seed, half, d):
    cap = as_cap(random_regular_graph(2 * half, d, seed=seed))
    paths = kpaths.k_shortest_paths(cap, k=4, max_hops=8)
    assert_path_properties(cap, paths, 4)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10 ** 6), bias=st.sampled_from([0.4, 0.7]))
def test_paths_properties_two_cluster(seed, bias):
    cap = as_cap(biased_two_cluster_graph(
        [4] * 6, [4] * 5, cross_bias=bias, seed=seed))
    paths = kpaths.k_shortest_paths(cap, k=4, max_hops=8)
    assert_path_properties(cap, paths, 4)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_paths_properties_fixed_seeds(seed):
    """Deterministic pin of the hypothesis properties — runs even where
    hypothesis is not installed (the shim skips the @given tests)."""
    cap = as_cap(random_regular_graph(12, 3, seed=seed))
    assert_path_properties(cap, kpaths.k_shortest_paths(
        cap, k=4, max_hops=8), 4)
    cap2 = as_cap(biased_two_cluster_graph(
        [4] * 6, [4] * 5, cross_bias=0.5, seed=seed))
    assert_path_properties(cap2, kpaths.k_shortest_paths(
        cap2, k=4, max_hops=8), 4)


def test_paths_on_padded_lane_never_touch_padding():
    """Embedding a graph into a larger zero-padded matrix (what plan
    packing does) adds no paths and no visits to padded nodes, and the
    real region enumerates identically."""
    cap = as_cap(random_regular_graph(8, 3, seed=4))
    padded = np.zeros((12, 12))
    padded[:8, :8] = cap
    p_pad = kpaths.k_shortest_paths(padded, k=3, max_hops=7)
    p_ref = kpaths.k_shortest_paths(cap, k=3, max_hops=7)
    assert np.all(p_pad[8:] == -1) and np.all(p_pad[:, 8:] == -1)
    assert np.all(p_pad < 8)  # -1 or a real node: padding never visited
    assert np.array_equal(p_pad[:8, :8], p_ref)
    assert_path_properties(padded, p_pad, 3)


def test_paths_on_server_coarsened_topology():
    """Enumeration holds on both sides of the server expansion: the
    leaf-expanded graph and the coarsened switch graph the engines
    actually solve."""
    t = random_regular_graph(10, 3, seed=6, servers=2)
    expanded = t.with_server_nodes()
    cap_x = as_cap(expanded)
    assert_path_properties(cap_x, kpaths.k_shortest_paths(
        cap_x, k=3, max_hops=8), 3)
    coarse = expanded.coarsen()
    cap = as_cap(coarse)
    assert np.array_equal(cap, as_cap(t))  # exact round trip
    paths = kpaths.k_shortest_paths(cap, k=4, max_hops=8)
    assert_path_properties(cap, paths, 4)
    dem = traffic.make("permutation", coarse.servers, seed=7)
    assert dem.shape == cap.shape  # the demand the engines route


def test_disconnected_demand_reports_zero():
    cap = np.zeros((4, 4))
    cap[0, 1] = cap[1, 0] = 1.0
    dem = np.zeros((4, 4))
    dem[0, 3] = 1.0
    assert routing.solve_ecmp(cap, dem, iters=30).throughput_lb == 0.0
    assert routing.solve_ksp(cap, dem, iters=30, k=2).throughput_lb == 0.0


def test_padded_batch_lane_matches_unpadded_solve():
    """An n=8 instance solved in a 12-wide padded lane (n_valid=8) gives
    the same certified bounds as the direct solve — padding is inert."""
    t = random_regular_graph(8, 3, seed=5, servers=2)
    cap = as_cap(t)
    dem = traffic.make("permutation", t.servers, seed=6)
    caps = np.zeros((1, 12, 12), np.float32)
    dems = np.zeros((1, 12, 12), np.float32)
    caps[0, :8, :8] = cap
    dems[0, :8, :8] = dem
    kw = dict(iters=120, max_hops=7)
    batch = routing.solve_ksp_batch(caps, dems, n_valid=np.array([8]), **kw)
    direct = routing.solve_ksp(cap, dem, **kw)
    assert batch.throughput_lb[0] == pytest.approx(direct.throughput_lb,
                                                   rel=1e-4)
    assert batch.throughput_ub[0] == pytest.approx(direct.throughput_ub,
                                                   rel=1e-4)
    eb = routing.solve_ecmp_batch(caps, dems, n_valid=np.array([8]),
                                  iters=60)
    ed = routing.solve_ecmp(cap, dem, iters=60)
    assert eb.throughput_lb[0] == pytest.approx(ed.throughput_lb, rel=1e-4)


@pytest.mark.parametrize("name", ["ecmp", "ksp"])
def test_one_batchplan_per_sweep_and_fresh_round_reuses_compiles(name):
    """The PR 5/9 plan contract on the routing engines: one solve_batch
    = one BatchPlan spanning every instance (executes == 1 per sweep),
    and a second fresh-instance round of the same shapes adds ZERO new
    routing-solver XLA compiles (shared compile keys across rounds)."""
    mk = lambda s: random_regular_graph(12, 3, seed=s, servers=2)  # noqa
    topos = [mk(s) for s in range(4)]
    dems = [traffic.make("permutation", t.servers, seed=9 + i)
            for i, t in enumerate(topos)]
    eng = get_engine(name, iters=40)
    res = eng.solve_batch(topos, dems)
    assert len(res) == 4 and all(r.bound == "lower" for r in res)
    stats = eng.last_plan
    assert stats.instances == 4        # ONE plan saw the whole sweep
    assert stats.chunks == stats.buckets == 1
    keys = stats.compile_keys
    c0 = compile_cache_sizes()
    topos2 = [mk(s + 50) for s in range(4)]
    dems2 = [traffic.make("permutation", t.servers, seed=90 + i)
             for i, t in enumerate(topos2)]
    eng.solve_batch(topos2, dems2)
    c1 = compile_cache_sizes()
    assert eng.last_plan.compile_keys == keys
    delta = {kk: c1[kk] - c0[kk] for kk in c1
             if kk.startswith("routing.")
             and c0[kk] is not None and c1[kk] is not None}
    assert delta and all(v == 0 for v in delta.values()), delta


def test_batchplan_refill_reuses_ksp_programs():
    """``BatchPlan.refill`` + ``execute(solver="ksp")``: the structural
    compile-key guarantee extends to the routing solvers."""
    topos = [random_regular_graph(10, 3, seed=s, servers=1)
             for s in range(3)]
    dems = [traffic.make("permutation", t.servers, seed=s)
            for s, t in enumerate(topos)]
    plan = BatchPlan.build(topos, dems)
    r1 = plan.execute(solver="ksp", iters=30)
    c0 = compile_cache_sizes()
    plan2 = plan.refill([random_regular_graph(10, 3, seed=s + 7)
                         for s in range(3)], dems)
    r2 = plan2.execute(solver="ksp", iters=30)
    c1 = compile_cache_sizes()
    assert plan2.stats.compile_keys == plan.stats.compile_keys
    delta = {kk: c1[kk] - c0[kk] for kk in c1
             if kk.startswith("routing.")
             and c0[kk] is not None and c1[kk] is not None}
    assert delta and all(v == 0 for v in delta.values()), delta
    assert len(r1) == len(r2) == 3
    assert all("ub" in s.meta and "final_util" in s.meta for s in r2)


def test_run_sweeps_meta_reduce_hook_and_aggregation_regression():
    """The meta_reduce hook lands engine-specific aggregates in
    SweepPoint.meta; with or without it, the existing lb_mean/gap_max
    bracket aggregation is bit-identical (the satellite bugfix)."""
    def build(x, seed):
        return random_regular_graph(12, int(x), seed=seed, servers=2)

    sw = Sweep(xs=(3.0,), runs=2, seed0=5)
    cert = get_engine("certified", iters=80)
    base = run_sweeps([(sw, build)], cert)[0]
    hooked = run_sweeps([(sw, build)], cert,
                        meta_reduce={"gap": max, "not_a_key": max})[0]
    for p0, p1 in zip(base, hooked):
        assert p1.mean == p0.mean and p1.values == p0.values
        assert p1.lb_mean == p0.lb_mean and p1.gap_max == p0.gap_max
        assert p0.meta == {}                     # no hook -> empty meta
        assert p1.meta == {"gap": p1.gap_max}    # max over runs == gap_max
        assert "not_a_key" not in p1.meta        # absent keys are skipped

    pts = run_sweeps([(sw, build)], get_engine("ecmp", iters=80),
                     meta_reduce={"ideal_gap_pct": np.mean})[0]
    assert all(p.meta["ideal_gap_pct"] >= -1e-3 for p in pts)


def test_seedcheck_flags_unseeded_rng():
    bad = "import numpy as np\nrng = np.random.default_rng()\n"
    assert unseeded_rng_calls(bad, "x.py") != []
    assert unseeded_rng_calls("np.random.seed()\n", "y.py") != []
    assert unseeded_rng_calls("r = np.random.RandomState()\n", "z.py") != []


def test_seedcheck_passes_seeded_rng():
    good = ("import numpy as np\n"
            "rng = np.random.default_rng(0)\n"
            "rng2 = np.random.default_rng(seed)\n"
            "np.random.seed(4)\n"
            "r = np.random.RandomState(7)\n")
    assert unseeded_rng_calls(good, "x.py") == []
