"""ApspBackend registry: blocked Floyd-Warshall vs repeated squaring vs
the sparse-frontier ELL Bellman-Ford backend.

Every backend must produce the same distances, and — because they share
ONE fixed-point adjoint (``repro.core.apsp``; ``"ell-bf"`` routes the
same walk through the ELL-aware flavor) — the same SP-DAG subgradients,
tie-splitting included.  Weights quantized to multiples of 1/8 make
float32 path sums exact, so those checks can demand bit-equality rather
than tolerances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis import given, settings, st

from repro.core import apsp as apsp_mod
from repro.core import graphs, mcf, traffic
from repro.core.apsp import _INF, apsp, normalize_backend, resolve_backend
from repro.core.graphs import (biased_two_cluster_graph, degree_stats,
                               random_regular_ell, random_regular_graph)
from repro.kernels import ell as kell
from repro.kernels import fw as kfw
from repro.kernels import minplus


def _quantize(x):
    """Round to multiples of 1/8: float32-exact adds along any short path."""
    return np.round(np.asarray(x) * 8.0) / 8.0


def _ell_d_max(w):
    """Host-side table width of a dense weight matrix: max in-degree of
    the finite off-diagonal pattern (what ``graphs.degree_stats`` gives
    the solvers)."""
    a = np.asarray(w)
    fin = (a < _INF / 2) & ~np.eye(a.shape[0], dtype=bool)
    return max(1, int(fin.sum(axis=0).max()))


def _apsp_ell(w, **kw):
    return apsp(w, "ell-bf", None, _ell_d_max(w), **kw)


def _w_random(n, seed, p=0.35):
    """Random digraph lengths with _INF non-edges (reachability not
    guaranteed — backends must agree on unreachable pairs too)."""
    rng = np.random.default_rng(seed)
    w = _quantize(rng.uniform(0.5, 8.0, (n, n)))
    w = np.where(rng.random((n, n)) < p, w, _INF)
    np.fill_diagonal(w, 0.0)
    return jnp.asarray(w, jnp.float32)


def _w_topo(topo):
    cap = np.asarray(topo.cap)
    w = np.where(cap > 0, 1.0, _INF)
    np.fill_diagonal(w, 0.0)
    return jnp.asarray(w, jnp.float32)


def _w_cases():
    return {
        "random-sparse": _w_random(24, 0),
        "rrg-unit": _w_topo(random_regular_graph(32, 4, seed=1)),
        "two-cluster": _w_topo(biased_two_cluster_graph(
            [5] * 12, [3] * 12, 0.5, seed=2)),
    }


# ---------------------------------------------------------------------------
# forward: distances
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(_w_cases()))
def test_distances_bit_equal_across_backends(case):
    w = _w_cases()[case]
    d_sq = np.asarray(apsp(w, "squaring"))
    d_fw = np.asarray(apsp(w, "blocked-fw"))
    d_el = np.asarray(_apsp_ell(w))
    assert np.array_equal(d_sq, d_fw), \
        "squaring and blocked-fw disagree on quantized weights"
    assert np.array_equal(d_sq, d_el), \
        "ell-bf disagrees with the dense backends on quantized weights"


@pytest.mark.parametrize("case", sorted(_w_cases()))
def test_distances_match_scipy(case):
    sp = pytest.importorskip("scipy.sparse.csgraph")
    w = np.asarray(_w_cases()[case], np.float64)
    ref = sp.floyd_warshall(np.where(w > _INF / 2, np.inf, w))
    d = np.asarray(apsp(jnp.asarray(w, jnp.float32), "blocked-fw"))
    reach = np.isfinite(ref)
    assert np.all(d[~reach] > _INF / 2), "unreachable pairs must stay +inf"
    np.testing.assert_allclose(d[reach], ref[reach], rtol=1e-6, atol=1e-5)


def test_padded_lanes_leave_valid_block_unchanged():
    """Padding with _INF rows/cols (what n_valid lanes do) must not leak
    into the valid block on any backend."""
    w = _w_cases()["random-sparse"]
    n, m = w.shape[0], 40
    wp = np.full((m, m), _INF, np.float32)
    wp[:n, :n] = np.asarray(w)
    np.fill_diagonal(wp, 0.0)
    wp = jnp.asarray(wp)
    for backend in ("squaring", "blocked-fw", "ell-bf"):
        if backend == "ell-bf":
            d = np.asarray(_apsp_ell(w))
            dp = np.asarray(_apsp_ell(wp))
        else:
            d = np.asarray(apsp(w, backend))
            dp = np.asarray(apsp(wp, backend))
        assert np.array_equal(dp[:n, :n], d), backend
        off = ~np.eye(m - n, dtype=bool)
        assert np.all(dp[n:, n:][off] > _INF / 2), "padding stayed isolated"


def test_auto_matches_explicit_backends():
    w = _w_cases()["rrg-unit"]
    assert np.array_equal(np.asarray(apsp(w, "auto")),
                          np.asarray(apsp(w, "squaring")))


# ---------------------------------------------------------------------------
# backward: the shared SP-DAG subgradient
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(_w_cases()))
def test_subgradients_identical_across_backends(case):
    w = _w_cases()[case]
    n = w.shape[0]
    d_max = _ell_d_max(w)
    rng = np.random.default_rng(7)
    g = jnp.asarray(_quantize(rng.uniform(0.5, 2.0, (n, n))), jnp.float32)

    def loss(w, backend):
        dm = d_max if backend == "ell-bf" else None
        d = apsp(w, backend, None, dm)
        return jnp.sum(d * jnp.where(d < _INF / 2, g, 0.0))

    g_sq = np.asarray(jax.grad(loss)(w, "squaring"))
    g_fw = np.asarray(jax.grad(loss)(w, "blocked-fw"))
    g_el = np.asarray(jax.grad(loss)(w, "ell-bf"))
    assert np.array_equal(g_sq, g_fw), \
        "the shared adjoint must not depend on which forward ran"
    assert np.array_equal(g_sq, g_el), \
        "the ELL-aware adjoint must route bit-identical subgradients"
    # non-edges carry no subgradient
    assert np.all(g_sq[np.asarray(w) > _INF / 2] == 0.0)


def test_grad_is_unit_flow_on_shortest_paths():
    """Cotangent 1 on pair (0, 2) of the path 0-1-2 deposits unit flow on
    BOTH hops (gradient mass = path hop count)."""
    w = np.full((3, 3), _INF, np.float32)
    np.fill_diagonal(w, 0.0)
    w[0, 1] = w[1, 0] = 1.0
    w[1, 2] = w[2, 1] = 1.0

    def loss(w):
        return apsp(jnp.asarray(w), "blocked-fw")[0, 2]

    g = np.asarray(jax.grad(loss)(w))
    assert g[0, 1] == 1.0 and g[1, 2] == 1.0
    assert g.sum() == 2.0


def test_grad_splits_ties_evenly():
    """Two equal-length 2-hop routes: each carries half the unit flow on
    every backend."""
    w = np.full((4, 4), _INF, np.float32)
    np.fill_diagonal(w, 0.0)
    for a, b in [(0, 1), (0, 2), (1, 3), (2, 3)]:
        w[a, b] = w[b, a] = 1.0
    for backend in ("squaring", "blocked-fw", "ell-bf"):
        dm = 2 if backend == "ell-bf" else None
        g = np.asarray(jax.grad(
            lambda w: apsp(jnp.asarray(w), backend, None, dm)[0, 3])(w))
        np.testing.assert_allclose(g[0, 1], 0.5)
        np.testing.assert_allclose(g[1, 3], 0.5)
        np.testing.assert_allclose(g.sum(), 2.0)


@settings(max_examples=10)
@given(st.sampled_from([8, 12, 16]), st.integers(0, 99))
def test_backend_agreement_property(n, seed):
    w = _w_random(n, seed)
    d_sq = np.asarray(apsp(w, "squaring"))
    d_fw = np.asarray(apsp(w, "blocked-fw"))
    d_el = np.asarray(_apsp_ell(w))
    assert np.array_equal(d_sq, d_fw)
    assert np.array_equal(d_sq, d_el)


# ---------------------------------------------------------------------------
# ELL tables: sentinel pin, round-trips, validation
# ---------------------------------------------------------------------------

def test_ell_inf_sentinel_matches_apsp():
    """graphs (numpy-pure) and apsp must agree on the non-edge sentinel."""
    assert graphs._ELL_INF == _INF


def _topo_families():
    return {
        "rrg": random_regular_graph(24, 4, seed=0),
        "two-cluster": biased_two_cluster_graph([5] * 12, [3] * 12, 0.5,
                                                seed=2),
        "power-law": graphs.random_graph_from_degrees(
            graphs.power_law_degrees(20, 3, 8, 2.5, seed=4), seed=5),
    }


@pytest.mark.parametrize("family", sorted(_topo_families()))
def test_to_ell_round_trips_every_family(family):
    topo = _topo_families()[family]
    n = topo.n
    g = topo.to_ell()
    g.validate()
    want = np.where(np.asarray(topo.cap) > 0, 1.0, _INF).astype(np.float32)
    np.fill_diagonal(want, 0.0)
    assert np.array_equal(g.to_dense(), want)
    # asymmetric per-link lengths survive the round trip too
    rng = np.random.default_rng(9)
    lengths = _quantize(rng.uniform(0.5, 4.0, (n, n))).astype(np.float32)
    g2 = topo.to_ell(lengths=lengths)
    g2.validate()
    want2 = np.where(np.asarray(topo.cap) > 0, lengths, _INF)
    np.fill_diagonal(want2, 0.0)
    assert np.array_equal(g2.to_dense(), want2.astype(np.float32))
    # the traceable packer produces the same tables from the dense matrix
    idx, wgt = apsp_mod._pack_ell(jnp.asarray(want2, jnp.float32), g2.d_max)
    assert np.array_equal(np.asarray(idx), g2.idx)
    assert np.array_equal(np.asarray(wgt), g2.wgt)


def test_to_ell_rejects_truncating_d_max():
    topo = random_regular_graph(16, 4, seed=0)
    with pytest.raises(ValueError, match="silently drop"):
        topo.to_ell(d_max=3)


def test_degree_stats_matches_table_width():
    for family, topo in sorted(_topo_families().items()):
        d_max, mean = degree_stats(topo.cap)
        assert d_max == topo.to_ell().d_max, family
        deg = (np.asarray(topo.cap) > 0).sum(axis=1)
        assert mean == pytest.approx(deg[deg > 0].mean()), family


def test_random_regular_ell_matches_scipy():
    sp = pytest.importorskip("scipy.sparse.csgraph")
    g = random_regular_ell(64, 4, seed=3)
    g.validate()
    assert g.d_max == 4
    w = np.asarray(g.to_dense(), np.float64)
    ref = sp.floyd_warshall(np.where(w > _INF / 2, np.inf, w))
    d, _ = kell.ell_bf_apsp(jnp.asarray(g.idx), jnp.asarray(g.wgt))
    np.testing.assert_allclose(np.asarray(d), ref, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# the ell-bf backend: convergence, kernels, padded-chunk regression
# ---------------------------------------------------------------------------

def test_ell_bf_converges_within_diameter_plus_one():
    """The relaxation is at least one hop of progress per round, so the
    fixed point lands in <= diameter + 1 rounds (the +1 detects it)."""
    for n, r, seed in ((32, 4, 0), (64, 4, 1), (48, 6, 2)):
        g = random_regular_ell(n, r, seed=seed)
        d, rounds = kell.ell_bf_apsp(jnp.asarray(g.idx), jnp.asarray(g.wgt))
        d = np.asarray(d)
        assert np.all(d < _INF / 2), "r-regular construction is connected"
        diameter = int(d.max())   # unit weights: distance = hop count
        assert int(rounds) <= diameter + 1, (n, r, seed)


def test_ell_bf_max_rounds_caps_compile_key():
    g = random_regular_ell(32, 4, seed=0)
    full, _ = kell.ell_bf_apsp(jnp.asarray(g.idx), jnp.asarray(g.wgt))
    capped, rounds = kell.ell_bf_apsp(jnp.asarray(g.idx),
                                      jnp.asarray(g.wgt), max_rounds=2)
    assert int(rounds) <= 2
    # a 2-round cap covers exactly the <= 3-hop pairs (init is one hop)
    d = np.asarray(full)
    c = np.asarray(capped)
    assert np.array_equal(c[d <= 3], d[d <= 3])


def test_ell_bf_streamed_matches_full_solve():
    g = random_regular_ell(64, 4, seed=5)
    d_full, _ = kell.ell_bf_apsp(jnp.asarray(g.idx), jnp.asarray(g.wgt))
    d_str, rounds = kell.ell_bf_apsp_streamed(g.idx, g.wgt, block=16)
    assert np.array_equal(d_str, np.asarray(d_full))
    assert rounds >= 1


def test_ell_pallas_round_matches_jacobi_reference():
    """One Pallas relaxation round (interpret mode) == the plain Jacobi
    update min(m, min_j wgt[:, j] + m[idx[:, j], :]) with per-tile changed
    flags."""
    g = random_regular_ell(32, 4, seed=7)
    idx, wgt = jnp.asarray(g.idx), jnp.asarray(g.wgt)
    m = kell._full_init(idx, wgt)
    ref = np.asarray(m)
    # cand[t, j, s] = wgt[t, j] + m[idx[t, j], s]
    cand = np.asarray(wgt)[:, :, None] + np.asarray(m)[np.asarray(g.idx)]
    ref2 = np.minimum(ref, cand.min(axis=1))
    out, changed = kell.ell_relax_round_pallas(m, idx, wgt, tile=8,
                                               interpret=True)
    assert np.array_equal(np.asarray(out), ref2)
    tiles = np.asarray(changed)
    per_tile = (ref2 != ref).any(axis=1).reshape(-1, 8).any(axis=1)
    assert np.array_equal(tiles, per_tile)
    # converged input reports no change anywhere
    d, _ = kell.ell_bf_apsp(idx, wgt)
    _, quiet = kell.ell_relax_round_pallas(jnp.asarray(np.asarray(d).T),
                                           idx, wgt, tile=8, interpret=True)
    assert not np.asarray(quiet).any()


def test_ell_bf_requires_static_d_max():
    w = _w_cases()["rrg-unit"]
    with pytest.raises(ValueError, match="d_max"):
        apsp(w, "ell-bf")


def test_sp_dag_grad_padded_chunks_bit_identical(monkeypatch):
    """Regression (PR 8): ``_sp_dag_grad`` used to relax the fully-padded
    all-_INF chunk rows; masked-out chunking must not perturb bits.  A
    tiny element budget forces c=5 on n=24 (pad=1) for the dense adjoint
    and a narrow target chunk for the ELL one; both must reproduce the
    unchunked subgradients exactly."""
    w = _w_cases()["random-sparse"]
    n = w.shape[0]
    d_max = _ell_d_max(w)
    d = apsp(w, "squaring")
    rng = np.random.default_rng(11)
    g = jnp.asarray(_quantize(rng.uniform(0.5, 2.0, (n, n))), jnp.float32)
    g = jnp.where(d < _INF / 2, g, 0.0)
    ref_dense = np.asarray(apsp_mod._sp_dag_grad(w, d, g))
    ref_ell = np.asarray(apsp_mod._sp_dag_grad_ell(w, d, g, d_max))
    assert np.array_equal(ref_dense, ref_ell)
    monkeypatch.setattr(apsp_mod, "_BWD_ELEMS", n * n * 5)  # c=5, pad=1
    pad_dense = np.asarray(apsp_mod._sp_dag_grad(w, d, g))
    monkeypatch.setattr(apsp_mod, "_BWD_ELEMS", n * d_max * 5)
    pad_ell = np.asarray(apsp_mod._sp_dag_grad_ell(w, d, g, d_max))
    assert np.array_equal(pad_dense, ref_dense), \
        "dense adjoint changed bits under chunk padding"
    assert np.array_equal(pad_ell, ref_ell), \
        "ELL adjoint changed bits under chunk padding"


def test_ell_bf_vmaps_like_dense_backends():
    ws = jnp.stack([_w_topo(random_regular_graph(16, 4, seed=s))
                    for s in range(3)])
    d_max = _ell_d_max(ws[0])

    def solve(w):
        return apsp(w, "ell-bf", None, d_max)

    batched = np.asarray(jax.vmap(solve)(ws))
    for i in range(ws.shape[0]):
        assert np.array_equal(batched[i], np.asarray(solve(ws[i])))
        assert np.array_equal(batched[i],
                              np.asarray(apsp(ws[i], "squaring")))


# ---------------------------------------------------------------------------
# the tiled Pallas kernel itself (4-phase path, interpret mode)
# ---------------------------------------------------------------------------

def test_fw_pallas_tiles_match_jnp():
    w = _w_random(32, 3)
    tiled = kfw.fw_apsp_pallas(w, t=8, chunk=8, interpret=True)   # 4x4 tiles
    plain = kfw.fw_apsp_jnp(w)
    assert np.array_equal(np.asarray(tiled), np.asarray(plain))


def test_fw_pallas_single_tile_fast_path():
    w = _w_random(16, 4)
    one = kfw.fw_apsp_pallas(w, t=16, chunk=8, interpret=True)
    assert np.array_equal(np.asarray(one), np.asarray(kfw.fw_apsp_jnp(w)))


def test_fw_pallas_validates_shapes():
    with pytest.raises(ValueError, match="square"):
        kfw.fw_apsp_pallas(jnp.zeros((8, 12)), t=4, interpret=True)
    with pytest.raises(ValueError, match="multiple of the"):
        kfw.fw_apsp_pallas(jnp.zeros((10, 10)), t=4, interpret=True)
    with pytest.raises(ValueError, match="chunk"):
        kfw.fw_apsp_pallas(jnp.zeros((16, 16)), t=8, chunk=3,
                           interpret=True)


# ---------------------------------------------------------------------------
# registry plumbing + solver integration
# ---------------------------------------------------------------------------

def test_normalize_backend_mapping():
    assert normalize_backend(None, use_pallas=False) == "auto"
    assert normalize_backend(None, use_pallas=True) == "squaring-pallas"
    assert normalize_backend(True) == "squaring-pallas"    # legacy bool slot
    assert normalize_backend(False) == "squaring"
    assert normalize_backend("blocked-fw") == "blocked-fw"
    with pytest.raises(ValueError, match="unknown APSP backend"):
        normalize_backend("dijkstra")


def test_resolve_backend_threshold_is_static():
    thr = apsp_mod.AUTO_THRESHOLD
    assert resolve_backend("auto", thr) == "blocked-fw"
    assert resolve_backend("auto", thr - 1) == "squaring"
    assert resolve_backend("squaring", thr) == "squaring"


def test_resolve_backend_goes_sparse_with_density():
    thr, sparse = apsp_mod.AUTO_THRESHOLD, apsp_mod.SPARSE_THRESHOLD
    assert resolve_backend("auto", thr, mean_degree=sparse) == "ell-bf"
    assert resolve_backend("auto", thr, mean_degree=sparse + 1.0) \
        == "blocked-fw"
    # density never overrides the small-n dense pick or an explicit name
    assert resolve_backend("auto", thr - 1, mean_degree=4.0) == "squaring"
    assert resolve_backend("blocked-fw", thr, mean_degree=4.0) \
        == "blocked-fw"


def test_resolve_backend_density_keeps_dense_keys_unchanged():
    """Host-side density resolution must not churn dense jit/AOT cache
    keys: dense outcomes pass the name through verbatim with d_max None;
    only a sparse resolution returns a concrete ("ell-bf", width)."""
    cap = np.asarray(random_regular_graph(24, 4, seed=0).cap)
    assert mcf.resolve_backend_density("auto", cap, n=24) == ("auto", None)
    assert mcf.resolve_backend_density("squaring", cap, n=9999) \
        == ("squaring", None)
    bk, d_max = mcf.resolve_backend_density(
        "auto", cap, n=apsp_mod.AUTO_THRESHOLD)
    assert (bk, d_max) == ("ell-bf", 4)
    # caller-supplied hints skip the capacity scan entirely
    assert mcf.resolve_backend_density(
        "ell-bf", None, n=4096, d_max=16) == ("ell-bf", 16)


def test_solve_dual_matches_across_backends():
    topo = random_regular_graph(16, 4, seed=0, servers=3)
    dem = traffic.make("permutation", topo.servers, seed=1)
    r_sq = mcf.solve_dual(topo, dem, iters=80, backend="squaring")
    r_fw = mcf.solve_dual(topo, dem, iters=80, backend="blocked-fw")
    r_el = mcf.solve_dual(topo, dem, iters=80, backend="ell-bf")
    # identical distances + identical subgradients => identical descent
    # on the dense pair; ell-bf sums path lengths in a different order,
    # so unquantized descent weights cost it ~1 ulp per hop
    assert r_fw.throughput_ub == pytest.approx(r_sq.throughput_ub,
                                               rel=1e-5)
    assert r_el.throughput_ub == pytest.approx(r_sq.throughput_ub,
                                               rel=1e-5)
    assert r_fw.iterations == r_sq.iterations


# ---------------------------------------------------------------------------
# minplus kernel validation (was: bare asserts)
# ---------------------------------------------------------------------------

def test_minplus_matmul_pallas_raises_on_bad_inputs():
    with pytest.raises(ValueError, match="inner dimensions disagree"):
        minplus.minplus_matmul_pallas(jnp.zeros((128, 128)),
                                      jnp.zeros((256, 128)),
                                      interpret=True)
    with pytest.raises(ValueError, match="callers pad"):
        minplus.minplus_matmul_pallas(jnp.zeros((100, 128)),
                                      jnp.zeros((128, 128)),
                                      interpret=True)
    with pytest.raises(ValueError, match="chunk"):
        minplus.minplus_matmul_pallas(jnp.zeros((128, 128)),
                                      jnp.zeros((128, 128)),
                                      chunk=7, interpret=True)
