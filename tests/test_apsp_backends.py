"""ApspBackend registry: blocked Floyd-Warshall vs repeated squaring.

Every backend must produce the same distances, and — because they share
ONE fixed-point adjoint (``repro.core.apsp``) — the same SP-DAG
subgradients, tie-splitting included.  Weights quantized to multiples of
1/8 make float32 path sums exact, so those checks can demand
bit-equality rather than tolerances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis import given, settings, st

from repro.core import apsp as apsp_mod
from repro.core import mcf, traffic
from repro.core.apsp import _INF, apsp, normalize_backend, resolve_backend
from repro.core.graphs import biased_two_cluster_graph, random_regular_graph
from repro.kernels import fw as kfw
from repro.kernels import minplus


def _quantize(x):
    """Round to multiples of 1/8: float32-exact adds along any short path."""
    return np.round(np.asarray(x) * 8.0) / 8.0


def _w_random(n, seed, p=0.35):
    """Random digraph lengths with _INF non-edges (reachability not
    guaranteed — backends must agree on unreachable pairs too)."""
    rng = np.random.default_rng(seed)
    w = _quantize(rng.uniform(0.5, 8.0, (n, n)))
    w = np.where(rng.random((n, n)) < p, w, _INF)
    np.fill_diagonal(w, 0.0)
    return jnp.asarray(w, jnp.float32)


def _w_topo(topo):
    cap = np.asarray(topo.cap)
    w = np.where(cap > 0, 1.0, _INF)
    np.fill_diagonal(w, 0.0)
    return jnp.asarray(w, jnp.float32)


def _w_cases():
    return {
        "random-sparse": _w_random(24, 0),
        "rrg-unit": _w_topo(random_regular_graph(32, 4, seed=1)),
        "two-cluster": _w_topo(biased_two_cluster_graph(
            [5] * 12, [3] * 12, 0.5, seed=2)),
    }


# ---------------------------------------------------------------------------
# forward: distances
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(_w_cases()))
def test_distances_bit_equal_across_backends(case):
    w = _w_cases()[case]
    d_sq = apsp(w, "squaring")
    d_fw = apsp(w, "blocked-fw")
    assert np.array_equal(np.asarray(d_sq), np.asarray(d_fw)), \
        "squaring and blocked-fw disagree on quantized weights"


@pytest.mark.parametrize("case", sorted(_w_cases()))
def test_distances_match_scipy(case):
    sp = pytest.importorskip("scipy.sparse.csgraph")
    w = np.asarray(_w_cases()[case], np.float64)
    ref = sp.floyd_warshall(np.where(w > _INF / 2, np.inf, w))
    d = np.asarray(apsp(jnp.asarray(w, jnp.float32), "blocked-fw"))
    reach = np.isfinite(ref)
    assert np.all(d[~reach] > _INF / 2), "unreachable pairs must stay +inf"
    np.testing.assert_allclose(d[reach], ref[reach], rtol=1e-6, atol=1e-5)


def test_padded_lanes_leave_valid_block_unchanged():
    """Padding with _INF rows/cols (what n_valid lanes do) must not leak
    into the valid block on any backend."""
    w = _w_cases()["random-sparse"]
    n, m = w.shape[0], 40
    wp = np.full((m, m), _INF, np.float32)
    wp[:n, :n] = np.asarray(w)
    np.fill_diagonal(wp, 0.0)
    wp = jnp.asarray(wp)
    for backend in ("squaring", "blocked-fw"):
        d = np.asarray(apsp(w, backend))
        dp = np.asarray(apsp(wp, backend))
        assert np.array_equal(dp[:n, :n], d), backend
        off = ~np.eye(m - n, dtype=bool)
        assert np.all(dp[n:, n:][off] > _INF / 2), "padding stayed isolated"


def test_auto_matches_explicit_backends():
    w = _w_cases()["rrg-unit"]
    assert np.array_equal(np.asarray(apsp(w, "auto")),
                          np.asarray(apsp(w, "squaring")))


# ---------------------------------------------------------------------------
# backward: the shared SP-DAG subgradient
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(_w_cases()))
def test_subgradients_identical_across_backends(case):
    w = _w_cases()[case]
    n = w.shape[0]
    rng = np.random.default_rng(7)
    g = jnp.asarray(_quantize(rng.uniform(0.5, 2.0, (n, n))), jnp.float32)

    def loss(w, backend):
        return jnp.sum(apsp(w, backend) * jnp.where(
            apsp(w, backend) < _INF / 2, g, 0.0))

    g_sq = np.asarray(jax.grad(loss)(w, "squaring"))
    g_fw = np.asarray(jax.grad(loss)(w, "blocked-fw"))
    assert np.array_equal(g_sq, g_fw), \
        "the shared adjoint must not depend on which forward ran"
    # non-edges carry no subgradient
    assert np.all(g_sq[np.asarray(w) > _INF / 2] == 0.0)


def test_grad_is_unit_flow_on_shortest_paths():
    """Cotangent 1 on pair (0, 2) of the path 0-1-2 deposits unit flow on
    BOTH hops (gradient mass = path hop count)."""
    w = np.full((3, 3), _INF, np.float32)
    np.fill_diagonal(w, 0.0)
    w[0, 1] = w[1, 0] = 1.0
    w[1, 2] = w[2, 1] = 1.0

    def loss(w):
        return apsp(jnp.asarray(w), "blocked-fw")[0, 2]

    g = np.asarray(jax.grad(loss)(w))
    assert g[0, 1] == 1.0 and g[1, 2] == 1.0
    assert g.sum() == 2.0


def test_grad_splits_ties_evenly():
    """Two equal-length 2-hop routes: each carries half the unit flow on
    every backend."""
    w = np.full((4, 4), _INF, np.float32)
    np.fill_diagonal(w, 0.0)
    for a, b in [(0, 1), (0, 2), (1, 3), (2, 3)]:
        w[a, b] = w[b, a] = 1.0
    for backend in ("squaring", "blocked-fw"):
        g = np.asarray(jax.grad(
            lambda w: apsp(jnp.asarray(w), backend)[0, 3])(w))
        np.testing.assert_allclose(g[0, 1], 0.5)
        np.testing.assert_allclose(g[1, 3], 0.5)
        np.testing.assert_allclose(g.sum(), 2.0)


@settings(max_examples=10)
@given(st.sampled_from([8, 12, 16]), st.integers(0, 99))
def test_backend_agreement_property(n, seed):
    w = _w_random(n, seed)
    d_sq = np.asarray(apsp(w, "squaring"))
    d_fw = np.asarray(apsp(w, "blocked-fw"))
    assert np.array_equal(d_sq, d_fw)


# ---------------------------------------------------------------------------
# the tiled Pallas kernel itself (4-phase path, interpret mode)
# ---------------------------------------------------------------------------

def test_fw_pallas_tiles_match_jnp():
    w = _w_random(32, 3)
    tiled = kfw.fw_apsp_pallas(w, t=8, chunk=8, interpret=True)   # 4x4 tiles
    plain = kfw.fw_apsp_jnp(w)
    assert np.array_equal(np.asarray(tiled), np.asarray(plain))


def test_fw_pallas_single_tile_fast_path():
    w = _w_random(16, 4)
    one = kfw.fw_apsp_pallas(w, t=16, chunk=8, interpret=True)
    assert np.array_equal(np.asarray(one), np.asarray(kfw.fw_apsp_jnp(w)))


def test_fw_pallas_validates_shapes():
    with pytest.raises(ValueError, match="square"):
        kfw.fw_apsp_pallas(jnp.zeros((8, 12)), t=4, interpret=True)
    with pytest.raises(ValueError, match="multiple of the"):
        kfw.fw_apsp_pallas(jnp.zeros((10, 10)), t=4, interpret=True)
    with pytest.raises(ValueError, match="chunk"):
        kfw.fw_apsp_pallas(jnp.zeros((16, 16)), t=8, chunk=3,
                           interpret=True)


# ---------------------------------------------------------------------------
# registry plumbing + solver integration
# ---------------------------------------------------------------------------

def test_normalize_backend_mapping():
    assert normalize_backend(None, use_pallas=False) == "auto"
    assert normalize_backend(None, use_pallas=True) == "squaring-pallas"
    assert normalize_backend(True) == "squaring-pallas"    # legacy bool slot
    assert normalize_backend(False) == "squaring"
    assert normalize_backend("blocked-fw") == "blocked-fw"
    with pytest.raises(ValueError, match="unknown APSP backend"):
        normalize_backend("dijkstra")


def test_resolve_backend_threshold_is_static():
    thr = apsp_mod.AUTO_THRESHOLD
    assert resolve_backend("auto", thr) == "blocked-fw"
    assert resolve_backend("auto", thr - 1) == "squaring"
    assert resolve_backend("squaring", thr) == "squaring"


def test_solve_dual_matches_across_backends():
    topo = random_regular_graph(16, 4, seed=0, servers=3)
    dem = traffic.make("permutation", topo.servers, seed=1)
    r_sq = mcf.solve_dual(topo, dem, iters=80, backend="squaring")
    r_fw = mcf.solve_dual(topo, dem, iters=80, backend="blocked-fw")
    # identical distances + identical subgradients => identical descent
    assert r_fw.throughput_ub == pytest.approx(r_sq.throughput_ub,
                                               rel=1e-5)
    assert r_fw.iterations == r_sq.iterations


# ---------------------------------------------------------------------------
# minplus kernel validation (was: bare asserts)
# ---------------------------------------------------------------------------

def test_minplus_matmul_pallas_raises_on_bad_inputs():
    with pytest.raises(ValueError, match="inner dimensions disagree"):
        minplus.minplus_matmul_pallas(jnp.zeros((128, 128)),
                                      jnp.zeros((256, 128)),
                                      interpret=True)
    with pytest.raises(ValueError, match="callers pad"):
        minplus.minplus_matmul_pallas(jnp.zeros((100, 128)),
                                      jnp.zeros((128, 128)),
                                      interpret=True)
    with pytest.raises(ValueError, match="chunk"):
        minplus.minplus_matmul_pallas(jnp.zeros((128, 128)),
                                      jnp.zeros((128, 128)),
                                      chunk=7, interpret=True)
