"""Traffic matrix invariants (core.traffic)."""
import numpy as np
from tests._hypothesis import given, st

from repro.core import traffic


@given(st.lists(st.integers(1, 8), min_size=3, max_size=12),
       st.integers(0, 999))
def test_random_permutation_conservation(servers, seed):
    servers = np.asarray(servers)
    dem = traffic.random_permutation(servers, seed)
    assert np.all(np.diag(dem) == 0)
    # each server sends and receives exactly one unit, minus same-switch pairs
    assert dem.sum(axis=1).max() <= servers.max()
    assert dem.sum() <= servers.sum()
    assert dem.sum(axis=1).sum() == dem.sum(axis=0).sum()


def test_random_permutation_is_server_level_derangement():
    servers = np.full(10, 4)
    dem = traffic.random_permutation(servers, 3)
    # totals: 40 servers each send 1 flow; same-switch flows dropped
    assert 30 <= dem.sum() <= 40


def test_all_to_all():
    dem = traffic.all_to_all(np.array([2, 3, 1]))
    assert dem[0, 1] == 6 and dem[1, 0] == 6 and dem[2, 0] == 2
    assert np.all(np.diag(dem) == 0)


def test_all_to_one_targets_single_switch():
    dem = traffic.all_to_one(np.full(8, 3), seed=1)
    recv = dem.sum(axis=0)
    assert (recv > 0).sum() == 1


@given(st.floats(0.0, 1.0), st.integers(0, 99))
def test_stride_conserves_total_volume(frac, seed):
    servers = np.full(12, 5)
    dem = traffic.stride(servers, frac, seed)
    assert dem.sum() <= servers.sum()
    assert np.all(dem >= 0) and np.all(np.diag(dem) == 0)


def test_stride_full_is_tor_level():
    servers = np.full(10, 6)
    dem = traffic.stride(servers, 1.0, 0)
    rows = dem.sum(axis=1)
    assert np.all(rows == 6), "each ToR sends all its servers to one ToR"
    assert np.all((dem > 0).sum(axis=1) == 1)
