"""Traffic matrix invariants (core.traffic).

Property tests (hypothesis, skipped cleanly when it is not installed)
cover the structural invariants of every pattern; the plain tests pin the
same invariants on fixed instances so they always run, plus the
``random_permutation`` tiny-instance regression (the old 100-pass fixup
loop silently returned a non-derangement for < 2 servers).
"""
import numpy as np
import pytest
from tests._hypothesis import given, settings, st

from repro.core import traffic


# ---------------------------------------------------------------------------
# random_permutation
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(0, 8), min_size=2, max_size=12)
       .filter(lambda sv: sum(sv) >= 2),
       st.integers(0, 999))
def test_random_permutation_row_col_sums(servers, seed):
    """Every server sends one flow and receives one flow; a same-switch
    pair drops one from BOTH the switch's row and its column sum, so
    row sums == column sums elementwise and both are <= servers."""
    servers = np.asarray(servers)
    dem = traffic.random_permutation(servers, seed)
    sent = dem.sum(axis=1)
    recv = dem.sum(axis=0)
    assert np.all(np.diag(dem) == 0)
    assert np.all(dem >= 0)
    np.testing.assert_array_equal(sent, recv)
    assert np.all(sent <= servers)
    # total flows: all s servers send, minus the dropped same-switch pairs
    assert dem.sum() <= servers.sum()
    assert dem.sum() == traffic.num_flows(dem)


@given(st.integers(2, 40), st.integers(0, 99))
def test_random_permutation_single_switch_per_server_is_derangement(s, seed):
    """One server per switch: the permutation must be a full derangement —
    every switch sends exactly one flow and receives exactly one."""
    servers = np.ones(s, np.int64)
    dem = traffic.random_permutation(servers, seed)
    assert np.all(dem.sum(axis=1) == 1)
    assert np.all(dem.sum(axis=0) == 1)
    assert np.all(np.diag(dem) == 0)


def test_random_permutation_conservation_fixed():
    servers = np.asarray([3, 1, 4, 2, 5])
    dem = traffic.random_permutation(servers, 11)
    np.testing.assert_array_equal(dem.sum(axis=1), dem.sum(axis=0))
    assert np.all(dem.sum(axis=1) <= servers)


def test_random_permutation_is_server_level_derangement():
    servers = np.full(10, 4)
    dem = traffic.random_permutation(servers, 3)
    # totals: 40 servers each send 1 flow; same-switch flows dropped
    assert 30 <= dem.sum() <= 40


@pytest.mark.parametrize("servers", [[0], [1], [0, 0], [1, 0], [0, 1, 0]])
def test_random_permutation_under_two_servers_raises(servers):
    # regression: used to silently fall out of the fixup loop and return
    # an all-zero (or self-loop-only) demand matrix
    with pytest.raises(ValueError, match=">= 2 servers"):
        traffic.random_permutation(np.asarray(servers), seed=0)


def test_random_permutation_two_servers_deterministic():
    # the only derangement of two servers is the swap; on one switch the
    # flows are intra-switch and dropped, on two switches both survive
    dem = traffic.random_permutation(np.array([1, 1]), seed=5)
    assert dem[0, 1] == 1 and dem[1, 0] == 1 and dem.sum() == 2
    dem = traffic.random_permutation(np.array([2]), seed=5)
    assert dem.shape == (1, 1) and dem.sum() == 0


# ---------------------------------------------------------------------------
# all_to_all / all_to_one
# ---------------------------------------------------------------------------

def test_all_to_all():
    dem = traffic.all_to_all(np.array([2, 3, 1]))
    assert dem[0, 1] == 6 and dem[1, 0] == 6 and dem[2, 0] == 2
    assert np.all(np.diag(dem) == 0)


@given(st.lists(st.integers(0, 9), min_size=2, max_size=10))
def test_all_to_all_num_flows(servers):
    servers = np.asarray(servers)
    dem = traffic.all_to_all(servers)
    s = servers.sum()
    # every ordered cross-switch server pair carries one flow
    assert traffic.num_flows(dem) == s * s - (servers * servers).sum()
    assert np.all(np.diag(dem) == 0)


def test_all_to_one_targets_single_switch():
    dem = traffic.all_to_one(np.full(8, 3), seed=1)
    recv = dem.sum(axis=0)
    assert (recv > 0).sum() == 1


def test_all_to_one_zero_servers_raises():
    # regression: servers.sum() == 0 used to divide by zero in the
    # target-draw probabilities instead of failing with a clear message
    with pytest.raises(ValueError, match=">= 1 server"):
        traffic.all_to_one(np.zeros(4, np.int64), seed=0)


def test_all_to_one_single_occupied_switch_raises():
    # all servers on one switch: every flow would be intra-switch and the
    # demand matrix all-zero — reject early instead
    with pytest.raises(ValueError, match=">= 2 switches"):
        traffic.all_to_one(np.array([0, 7, 0]), seed=0)


def test_all_to_one_never_targets_empty_switch():
    # regression: a zero-server switch could previously never be drawn by
    # probability, but the draw ran over ALL switches; the target is now
    # drawn among occupied switches only — pin it across seeds
    servers = np.array([3, 0, 2, 0, 5])
    for seed in range(25):
        dem = traffic.all_to_one(servers, seed)
        target = int(np.flatnonzero(dem.sum(axis=0))[0])
        assert servers[target] > 0
        assert traffic.num_flows(dem) == servers.sum() - servers[target]


@given(st.lists(st.integers(1, 6), min_size=2, max_size=10),
       st.integers(0, 99))
def test_all_to_one_volume(servers, seed):
    servers = np.asarray(servers)
    dem = traffic.all_to_one(servers, seed)
    target = int(np.flatnonzero(dem.sum(axis=0))[0])
    # every other switch sends all its servers; the target sends nothing
    np.testing.assert_array_equal(
        np.delete(dem[:, target], target), np.delete(servers, target))
    assert dem[target, target] == 0
    assert traffic.num_flows(dem) == servers.sum() - servers[target]


# ---------------------------------------------------------------------------
# stride
# ---------------------------------------------------------------------------

@given(st.floats(0.0, 1.0), st.integers(0, 99))
def test_stride_conserves_total_volume(frac, seed):
    servers = np.full(12, 5)
    dem = traffic.stride(servers, frac, seed)
    assert dem.sum() <= servers.sum()
    assert np.all(dem >= 0) and np.all(np.diag(dem) == 0)


@given(st.integers(3, 12), st.integers(1, 6), st.integers(0, 99))
def test_stride_full_flow_conservation(n, per_switch, seed):
    """frac=1: a ToR-level permutation — each switch sends ALL its servers
    to exactly one other switch, and receives its predecessor's."""
    servers = np.full(n, per_switch)
    dem = traffic.stride(servers, 1.0, seed)
    np.testing.assert_array_equal(dem.sum(axis=1), servers)
    np.testing.assert_array_equal(dem.sum(axis=0), servers)
    assert np.all((dem > 0).sum(axis=1) == 1)
    assert np.all(np.diag(dem) == 0)


def test_stride_full_is_tor_level():
    servers = np.full(10, 6)
    dem = traffic.stride(servers, 1.0, 0)
    rows = dem.sum(axis=1)
    assert np.all(rows == 6), "each ToR sends all its servers to one ToR"
    assert np.all((dem > 0).sum(axis=1) == 1)


def test_stride_zero_frac_is_pure_permutation():
    servers = np.full(8, 3)
    dem = traffic.stride(servers, 0.0, seed=4)
    np.testing.assert_array_equal(dem.sum(axis=1), dem.sum(axis=0))
    assert np.all(dem.sum(axis=1) <= servers)


@pytest.mark.parametrize("frac", [-0.1, 1.5, 2.0, -3.0])
def test_stride_frac_out_of_range_raises(frac):
    # regression: frac > 1 used to crash deep inside rng.choice with an
    # opaque "Cannot take a larger sample than population" numpy error
    with pytest.raises(ValueError, match=rf"\[0, 1\].*{frac}"):
        traffic.stride(np.full(6, 2), frac, seed=0)


# ---------------------------------------------------------------------------
# make: seed contract
# ---------------------------------------------------------------------------

def test_make_deterministic_patterns_ignore_seed():
    servers = np.asarray([2, 3, 1, 4])
    a = traffic.make("all_to_all", servers, seed=0)
    b = traffic.make("all_to_all", servers, seed=999)
    np.testing.assert_array_equal(a, b)


def test_make_is_seed_deterministic():
    servers = np.full(8, 3)
    for name, kw in [("permutation", {}), ("all_to_one", {}),
                     ("stride", {"frac": 0.5})]:
        a = traffic.make(name, servers, seed=7, **kw)
        b = traffic.make(name, servers, seed=7, **kw)
        np.testing.assert_array_equal(a, b)


def test_stride_substream_does_not_collide_with_next_seed():
    """Regression for the sub-seed contract: stride used to derive its
    rest-permutation stream as ``seed + 1``, so ``stride(seed=k,
    frac=0)`` reproduced ``permutation(seed=k+1)`` exactly — a caller
    sweeping consecutive seeds sampled correlated traffic.  The
    sub-stream is now keyed as an independent ``(seed, tag)`` stream."""
    servers = np.full(10, 3)
    for seed in range(10):
        sub = traffic.stride(servers, 0.0, seed)   # frac=0: rest = all
        nxt = traffic.random_permutation(servers, seed + 1)
        assert not np.array_equal(sub, nxt), \
            f"stride seed={seed} aliases permutation seed={seed + 1}"


# ---------------------------------------------------------------------------
# registry / num_flows
# ---------------------------------------------------------------------------

# "adversarial" is the one pattern that needs the topology it attacks
# (and a search budget) — it gets its own suite in test_adversarial.py
_SAMPLED = sorted(set(traffic.PATTERNS) - {"adversarial"})


@settings(max_examples=10)
@given(st.sampled_from(_SAMPLED), st.integers(0, 99))
def test_every_pattern_shares_the_core_invariants(name, seed):
    servers = np.asarray([2, 3, 1, 4, 2, 2])
    dem = traffic.make(name, servers, seed)
    assert dem.shape == (6, 6)
    assert np.all(np.diag(dem) == 0), "same-switch flows never hit the net"
    assert np.all(dem >= 0)
    assert 0 < traffic.num_flows(dem) <= servers.sum() ** 2


def test_adversarial_pattern_requires_topology():
    with pytest.raises(ValueError, match="topo"):
        traffic.make("adversarial", np.full(6, 2), seed=0)
