"""Flow engines: exact LP oracle + JAX dual solver + bounds + decomposition."""
import numpy as np
import pytest
from tests._hypothesis import given, settings, st

from repro.core import bounds, decompose, graphs, lp, mcf, traffic


def ring(n):
    cap = np.zeros((n, n))
    for i in range(n):
        cap[i, (i + 1) % n] = cap[(i + 1) % n, i] = 1.0
    return cap


def test_lp_two_nodes_exact():
    cap = np.array([[0.0, 1.0], [1.0, 0.0]])
    dem = np.array([[0.0, 1.0], [1.0, 0.0]])
    res = lp.max_concurrent_flow(cap, dem)
    assert res.throughput == pytest.approx(1.0, abs=1e-6)
    assert res.mean_utilization == pytest.approx(1.0, abs=1e-6)


def test_lp_ring_known_value():
    # 4-ring, demand only between antipodal pairs (0<->2): two 2-hop paths
    cap = ring(4)
    dem = np.zeros((4, 4))
    dem[0, 2] = dem[2, 0] = 1.0
    res = lp.max_concurrent_flow(cap, dem)
    assert res.throughput == pytest.approx(2.0, abs=1e-5)


def test_lp_respects_cut():
    # two triangles joined by one edge: cut capacity 2 (both directions)
    cap = np.zeros((6, 6))
    for (u, v) in [(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3), (0, 3)]:
        cap[u, v] = cap[v, u] = 1.0
    dem = np.zeros((6, 6))
    for u in range(3):
        for v in range(3, 6):
            dem[u, v] = 1.0
    res = lp.max_concurrent_flow(cap, dem)
    assert res.throughput <= 2.0 / 9.0 + 1e-6


@settings(max_examples=6)
@given(st.integers(10, 18), st.integers(3, 5), st.integers(0, 99))
def test_dual_solver_upper_bounds_and_converges(n, r, seed):
    if n * r % 2:
        n += 1
    cap = graphs.random_regular_graph(n, r, seed)
    dem = traffic.random_permutation(np.full(n, 2), seed + 1)
    exact = lp.max_concurrent_flow(cap, dem, want_flows=False).throughput
    res = mcf.solve_dual(cap, dem, iters=500)
    assert res.throughput_ub >= exact - 1e-4, "dual iterate must upper-bound"
    assert res.throughput_ub <= exact * 1.06, "and converge within ~6%"


def test_dual_batch_matches_single():
    caps, dems = [], []
    for s in range(3):
        caps.append(graphs.random_regular_graph(12, 4, s))
        dems.append(traffic.random_permutation(np.full(12, 2), s))
    batch = mcf.solve_dual_batch(np.stack(caps), np.stack(dems), iters=300)
    for i in range(3):
        single = mcf.solve_dual(caps[i], dems[i], iters=300).throughput_ub
        assert batch[i] == pytest.approx(single, rel=1e-5)


def test_apsp_matches_scipy():
    cap = graphs.random_regular_graph(20, 3, 7)
    d_jax = mcf.aspl(cap)
    d_sp = lp.aspl_hops(cap)
    assert d_jax == pytest.approx(d_sp, rel=1e-5)


def test_weighted_aspl_masks_disconnected_pairs():
    # two disjoint triangles; demand only within the first component used to
    # be fine, but ANY zero-demand disconnected pair leaked ~1e18 into the
    # unmasked weighted sum
    cap = np.zeros((6, 6))
    for (u, v) in [(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)]:
        cap[u, v] = cap[v, u] = 1.0
    dem = np.zeros((6, 6))
    dem[0, 1] = dem[1, 2] = 2.0
    assert mcf.aspl(cap, dem) == pytest.approx(1.0)


def test_weighted_aspl_raises_on_demanded_disconnected_pair():
    cap = np.zeros((4, 4))
    cap[0, 1] = cap[1, 0] = cap[2, 3] = cap[3, 2] = 1.0
    dem = np.zeros((4, 4))
    dem[0, 2] = 1.0   # demand across the components
    with pytest.raises(ValueError, match="disconnected"):
        mcf.aspl(cap, dem)


# ---------------------------------------------------------------------------
# bounds (Theorem 1 + Cerf d* + Eqn 1/2)
# ---------------------------------------------------------------------------

def test_aspl_lower_bound_values():
    # complete graph: d* = 1
    assert bounds.aspl_lower_bound(5, 4) == pytest.approx(1.0)
    # ring-ish sparse: d* grows ~ log_{r-1}(n)
    assert bounds.aspl_lower_bound(1000, 3) > 5.0
    assert bounds.aspl_lower_bound(40, 10) < 2.0


@settings(max_examples=8)
@given(st.integers(10, 20), st.integers(3, 6), st.integers(0, 99))
def test_theorem1_holds_on_random_graphs(n, r, seed):
    if n * r % 2:
        n += 1
    if r >= n:
        return
    cap = graphs.random_regular_graph(n, r, seed)
    dem = traffic.random_permutation(np.full(n, 3), seed)
    th = lp.max_concurrent_flow(cap, dem, want_flows=False).throughput
    f = traffic.num_flows(dem)
    ub_measured_d = bounds.throughput_upper_bound(
        n, r, f, aspl=lp.aspl_hops(cap, dem))
    ub_dstar = bounds.throughput_upper_bound(n, r, f)
    assert th <= ub_measured_d * (1 + 1e-6)
    assert th <= ub_dstar * (1 + 1e-6)
    assert ub_measured_d <= ub_dstar * (1 + 1e-9) or True  # d* <= real D


def test_het_bound_and_cut_threshold():
    ub = bounds.het_throughput_upper_bound(
        total_capacity=400, cut_capacity=20, aspl=2.5, n1=50, n2=50)
    assert ub == pytest.approx(min(400 / (2.5 * 100), 20 * 100 / 5000))
    assert bounds.cut_threshold(1.0, 50, 50) == pytest.approx(50.0)


# ---------------------------------------------------------------------------
# decomposition T = C*U/(f*D*AS)
# ---------------------------------------------------------------------------

@settings(max_examples=5)
@given(st.integers(0, 9))
def test_decomposition_identity(seed):
    cap = graphs.random_regular_graph(16, 4, seed)
    dem = traffic.random_permutation(np.full(16, 3), seed)
    d = decompose.decompose(cap, dem)
    assert d.reconstructed == pytest.approx(d.throughput, rel=1e-4)
    assert d.stretch >= 1.0 - 1e-6
    assert 0 < d.utilization <= 1.0 + 1e-9


def test_utilization_by_class():
    topo = graphs.biased_two_cluster_graph([6] * 8, [4] * 8, 1.0, 0)
    dem = traffic.random_permutation(np.full(16, 2), 1)
    res = lp.max_concurrent_flow(topo, dem)
    util = decompose.utilization_by_class(res, topo.labels)
    assert set(util) <= {(0, 0), (0, 1), (1, 1)}
    assert all(0 <= v <= 1 + 1e-9 for v in util.values())
