"""Primal Frank–Wolfe solver + certified brackets (PR 4).

Covers: lower-bound correctness vs the exact LP, the free dual upper bound,
padded batching == per-instance solves through the ``BatchPlan`` primal
path, early stopping, unroutable demand, the PrimalEngine/CertifiedEngine
result contracts, and bracket aggregation in ``run_sweeps``.
"""
import numpy as np
import pytest

from repro.core import graphs, lp, mcf, primal, traffic
from repro.core.engine import (CertifiedEngine, DualEngine, PrimalEngine,
                               Sweep, get_engine, run_sweep)
from repro.core.plan import BatchPlan


def _instance(n, seed, r=4, servers=3):
    topo = graphs.random_regular_graph(n, r, seed, servers=servers)
    dem = traffic.make("permutation", topo.servers, seed + 1)
    return topo, dem


# ---------------------------------------------------------------------------
# solver core
# ---------------------------------------------------------------------------

def test_primal_brackets_the_exact_optimum():
    topo, dem = _instance(16, 0)
    exact = lp.max_concurrent_flow(topo, dem, want_flows=False).throughput
    res = primal.solve_primal(topo, dem, iters=500)
    assert res.throughput_lb <= exact * (1 + 1e-4), \
        "primal iterate must lower-bound the optimum"
    assert exact <= res.throughput_ub * (1 + 1e-4), \
        "the riding dual bound must upper-bound it"
    assert res.throughput_lb >= exact * 0.94, "and converge within ~6%"
    assert res.gap == pytest.approx(
        (res.throughput_ub - res.throughput_lb) / res.throughput_ub)
    assert res.iterations == 500
    assert res.final_util > 0


def test_primal_ub_matches_mcf_dual():
    # the fused loop's dual descent is the same trajectory mcf runs
    topo, dem = _instance(14, 3)
    fused = primal.solve_primal(topo, dem, iters=400)
    dual = mcf.solve_dual(topo, dem, iters=400)
    assert fused.throughput_ub == pytest.approx(dual.throughput_ub, rel=5e-3)


def test_primal_padded_batch_matches_single():
    topo, dem = _instance(16, 0)
    ref = primal.solve_primal(topo, dem, iters=300)
    capp = np.zeros((1, 32, 32), np.float32)
    demp = np.zeros((1, 32, 32), np.float32)
    capp[0, :16, :16] = topo.cap
    demp[0, :16, :16] = dem
    res = primal.solve_primal_batch(capp, demp, n_valid=np.array([16]),
                                    iters=300)
    # node padding reorders float reductions, which can flip individual
    # line-search bisections: the FW trajectory (and so the lb) matches to
    # a few 1e-3, the dual ub more tightly
    assert res.throughput_lb[0] == pytest.approx(ref.throughput_lb, rel=5e-3)
    assert res.throughput_ub[0] == pytest.approx(ref.throughput_ub, rel=1e-3)
    assert res.iterations[0] == 300


def test_primal_early_stop_keeps_certification():
    topo, dem = _instance(16, 5)
    full = primal.solve_primal(topo, dem, iters=1500)
    early = primal.solve_primal(topo, dem, iters=1500, tol=1e-4)
    assert early.iterations < 1500, "tolerance reached => early exit"
    assert early.iterations % 25 == 0, "stops on a check boundary"
    # both are certified: early lb below full lb (less averaging), both
    # below the ub
    assert early.throughput_lb <= full.throughput_lb * (1 + 1e-5)
    assert early.throughput_lb <= early.throughput_ub
    assert early.throughput_lb == pytest.approx(full.throughput_lb,
                                                rel=0.05)


def test_primal_tol_zero_never_stops_early():
    topo, dem = _instance(12, 7)
    res = primal.solve_primal(topo, dem, iters=120, tol=0.0)
    assert res.iterations == 120


def test_primal_unroutable_demand_reports_zero_lb():
    cap = np.zeros((4, 4))
    cap[0, 1] = cap[1, 0] = cap[2, 3] = cap[3, 2] = 1.0
    dem = np.zeros((4, 4))
    dem[0, 1] = 1.0
    dem[0, 2] = 1.0    # demand across disconnected components
    res = primal.solve_primal(cap, dem, iters=50)
    assert res.throughput_lb == 0.0, "no feasible flow routes all demand"
    assert res.throughput_ub < 1e-6, "dual agrees theta* = 0"


def test_primal_batch_empty_and_mismatch():
    empty = primal.solve_primal_batch([], [])
    assert isinstance(empty, primal.PrimalBatchResult)
    assert len(empty) == 0 and list(empty) == []
    with pytest.raises(ValueError, match="equal length"):
        primal.solve_primal_batch([np.eye(4)], [])


# ---------------------------------------------------------------------------
# BatchPlan primal path
# ---------------------------------------------------------------------------

def test_plan_primal_solver_matches_per_instance():
    insts = [_instance(n, s) for s, n in enumerate([12, 14, 16, 20])]
    topos = [t for t, _ in insts]
    dems = [d for _, d in insts]
    plan = BatchPlan.build(topos, dems, bucket="pow2", devices=1)
    out = plan.execute(solver="primal", iters=300)
    for (topo, dem), got in zip(insts, out):
        ref = primal.solve_primal(topo, dem, iters=300)
        assert got.value == pytest.approx(ref.throughput_lb, rel=1e-3)
        assert got.meta["ub"] == pytest.approx(ref.throughput_ub, rel=1e-3)
        assert got.meta["final_util"] == pytest.approx(ref.final_util,
                                                       rel=1e-3)


def test_plan_unknown_solver_raises():
    topo, dem = _instance(12, 0)
    plan = BatchPlan.build([topo], [dem], devices=1)
    with pytest.raises(ValueError, match="unknown plan solver"):
        plan.execute(solver="simplex", iters=10)


def test_primal_plan_reuses_dual_plan_shapes():
    # primal lanes ride the same buckets/chunks/sharding: identical plans
    insts = [_instance(n, s) for s, n in enumerate([12, 16, 16, 20, 24])]
    topos = [t for t, _ in insts]
    dems = [d for _, d in insts]
    dual_eng = DualEngine(iters=50, devices=1, max_lanes=2)
    prim_eng = PrimalEngine(iters=50, devices=1, max_lanes=2)
    assert dual_eng.plan(topos, dems).stats.compile_keys == \
        prim_eng.plan(topos, dems).stats.compile_keys
    prim_eng.solve_batch(topos, dems)
    assert prim_eng.last_plan.compile_keys == \
        dual_eng.plan(topos, dems).stats.compile_keys


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------

def test_primal_engine_result_contract():
    topo, dem = _instance(16, 2)
    eng = get_engine("primal", iters=200)
    single = eng.solve(topo, dem)
    assert single.engine == "primal" and single.bound == "lower"
    assert not single.is_upper_bound
    assert set(single.meta) == {"iterations", "final_util", "ub"}
    [batched] = eng.solve_batch([topo], [dem])
    assert batched.throughput == pytest.approx(single.throughput, rel=1e-3)
    assert batched.bound == "lower"
    assert {"iterations", "final_util", "ub", "bucket", "chunk",
            "plan"} <= set(batched.meta)


def test_certified_engine_bracket_contract():
    insts = [_instance(n, s) for s, n in enumerate([12, 16])]
    eng = get_engine("certified", iters=200)
    out = eng.solve_batch([t for t, _ in insts], [d for _, d in insts])
    for (topo, dem), got in zip(insts, out):
        assert got.engine == "certified" and got.bound == "bracket"
        assert got.is_upper_bound and got.throughput == got.meta["ub"]
        assert 0 <= got.meta["lb"] <= got.meta["ub"]
        assert got.meta["gap"] == pytest.approx(
            (got.meta["ub"] - got.meta["lb"]) / got.meta["ub"])
        single = eng.solve(topo, dem)
        assert single.bound == "bracket"
        assert single.meta["lb"] == pytest.approx(got.meta["lb"], rel=1e-3)
        assert single.meta["ub"] == pytest.approx(got.meta["ub"], rel=1e-3)


def test_dual_engine_meta_unchanged_by_refactor():
    # the planned-engine refactor must not leak primal keys into dual meta
    topo, dem = _instance(12, 1)
    eng = DualEngine(iters=100)
    [got] = eng.solve_batch([topo], [dem])
    assert set(got.meta) == {"iterations", "final_ratio", "batch_size",
                             "bucket", "padded_n", "nodes", "chunk",
                             "chunks", "devices", "plan"}
    assert got.bound == "upper"


def test_certified_engine_registry_kwargs():
    eng = get_engine("certified", iters=30, bucket=None, devices=1,
                     max_lanes=4)
    assert isinstance(eng, CertifiedEngine)
    assert eng.bucket is None and eng.max_lanes == 4
    with pytest.raises(ValueError, match="bucket mode"):
        get_engine("certified", bucket="fib")


# ---------------------------------------------------------------------------
# sweep bracket aggregation
# ---------------------------------------------------------------------------

def test_run_sweep_aggregates_brackets():
    def build(x, seed):
        return graphs.random_regular_graph(12, 4, seed, servers=3)

    sweep = Sweep(xs=(0.0, 1.0), runs=2)
    pts = run_sweep(sweep, build, engine=get_engine("certified", iters=100))
    for p in pts:
        assert p.lb_mean is not None and p.gap_max is not None
        assert p.lb_mean <= p.mean * (1 + 1e-6)
        assert 0 <= p.gap_max < 1
    # non-bracket engines leave the fields None
    pts = run_sweep(sweep, build, engine=get_engine("dual", iters=100))
    assert all(p.lb_mean is None and p.gap_max is None for p in pts)
